//! Row-level checks: decode each stored row *back* from its don't-care
//! structure and hold it against the reduced rule table.
//!
//! Under the paper's adaptive unary scheme (§II.A.4) a well-formed
//! feature field is always `0^a x^b 1^c` with `c ≥ 1`:
//! [`FeatureEncoder::encode_rule`] emits the lower-bound unary code
//! `u_LB` (ones packed at the low-order end) with the positions where
//! `XOR(u_LB, u_UB) = 1` replaced by don't-cares. Decoding inverts
//! that — `LB = c − 1`, `UB = n − 1 − a` — so spans and valid fields
//! are in bijection, and comparing a stored field against a re-encoded
//! rule reduces to comparing two spans. Any other field shape cannot
//! come out of the compiler and is a `row-encoding` error.
//!
//! All re-encoding here is panic-free: `FeatureEncoder::encode_rule`
//! aborts the process when a rule threshold is missing from the
//! encoder's set, so the verifier re-derives spans itself and turns
//! every violation into a [`Diagnostic`] instead.

use crate::compiler::encode::trits_to_string;
use crate::compiler::{Comparator, FeatureEncoder, Lut, Rule, Trit};

use super::{Diagnostic, Severity};

/// One row decoded into per-feature range-index spans
/// (`spans[f] = (lb, ub)`, both inclusive), plus its class and its
/// original row index (rows that fail to decode are skipped, so the
/// index is not positional).
#[derive(Clone, Debug)]
pub struct RowBox {
    pub row: usize,
    pub class: usize,
    pub spans: Vec<(usize, usize)>,
}

/// Decode one feature field. Returns the `(lb, ub)` span, or a
/// human-readable description of the shape violation.
pub fn decode_field(field: &[Trit]) -> Result<(usize, usize), String> {
    let n = field.len();
    let zeros = field.iter().take_while(|&&t| t == Trit::Zero).count();
    let xs = field[zeros..].iter().take_while(|&&t| t == Trit::X).count();
    let ones = field[zeros + xs..].iter().take_while(|&&t| t == Trit::One).count();
    if zeros + xs + ones != n {
        return Err(format!(
            "field {:?} is not of the adaptive unary shape 0*x*1+",
            trits_to_string(field)
        ));
    }
    if ones == 0 {
        return Err(format!(
            "field {:?} has no trailing '1' — it matches no range index",
            trits_to_string(field)
        ));
    }
    Ok((ones - 1, ones - 1 + xs))
}

/// Render a span back to the field string it must encode as.
fn span_field_string(lb: usize, ub: usize, n_bits: usize) -> String {
    let mut s = String::with_capacity(n_bits);
    for _ in 0..n_bits.saturating_sub(ub + 1) {
        s.push('0');
    }
    for _ in lb..ub {
        s.push('x');
    }
    for _ in 0..lb + 1 {
        s.push('1');
    }
    s
}

/// Render a span as the half-open value interval it covers.
pub fn span_interval(enc: &FeatureEncoder, lb: usize, ub: usize) -> String {
    let ths = enc.thresholds();
    let lo = if lb == 0 { "-inf".to_string() } else { format!("{:.4}", ths[lb - 1]) };
    let hi = if ub >= ths.len() { "+inf".to_string() } else { format!("{:.4}", ths[ub]) };
    format!("({lo}, {hi}]")
}

/// Panic-free re-derivation of the span a reduced rule must encode as.
/// Mirrors `FeatureEncoder::encode_rule`, but a threshold missing from
/// the encoder set (or an inverted bound pair) comes back as `Err`
/// instead of aborting the process.
fn rule_span(enc: &FeatureEncoder, rule: &Rule) -> Result<(usize, usize), String> {
    let position = |th: f64| enc.thresholds().iter().position(|&t| t == th);
    let (lo, hi) = rule.bounds();
    let lb = if lo == f64::NEG_INFINITY {
        0
    } else {
        match position(lo) {
            Some(t) => t + 1,
            None => return Err(format!("rule lower bound {lo} is not an encoder threshold")),
        }
    };
    let ub = if hi == f64::INFINITY {
        enc.n_bits() - 1
    } else {
        match position(hi) {
            Some(t) => t,
            None => return Err(format!("rule upper bound {hi} is not an encoder threshold")),
        }
    };
    if lb > ub {
        return Err(format!("rule covers an empty value range ({lo}, {hi}]"));
    }
    Ok((lb, ub))
}

/// Comparator-level well-formedness: thresholds must be finite where
/// the comparator reads them and ordered for `InBetween` — the
/// "thresholds monotone along each path" half of the precision check
/// (an inverted pair means the source path contradicted itself).
fn rule_shape_error(rule: &Rule) -> Option<String> {
    match rule.comparator {
        Comparator::None => None,
        Comparator::Le if !rule.th1.is_finite() => {
            Some(format!("LE rule has non-finite threshold {}", rule.th1))
        }
        Comparator::Gt if !rule.th1.is_finite() => {
            Some(format!("GT rule has non-finite threshold {}", rule.th1))
        }
        Comparator::InBetween if !(rule.th1.is_finite() && rule.th2.is_finite()) => {
            Some(format!(
                "IN-BETWEEN rule has non-finite thresholds ({}, {})",
                rule.th1, rule.th2
            ))
        }
        Comparator::InBetween if rule.th1 >= rule.th2 => Some(format!(
            "IN-BETWEEN thresholds not monotone along the path: {} >= {}",
            rule.th1, rule.th2
        )),
        _ => None,
    }
}

/// All row-level checks for one bank. Emits diagnostics into `out` and
/// returns the successfully decoded rows for the space checks.
pub fn check_rows(bank: usize, lut: &Lut, out: &mut Vec<Diagnostic>) -> Vec<RowBox> {
    let diag = |sev, check, msg: String| Diagnostic::new(sev, check, msg).bank(bank);

    // Adaptive-precision consistency of the encoders themselves.
    for (f, enc) in lut.encoders.iter().enumerate() {
        let ths = enc.thresholds();
        if ths.iter().any(|t| !t.is_finite()) {
            out.push(diag(
                Severity::Error,
                "precision",
                format!("feature {f}: encoder thresholds contain a non-finite value"),
            ));
        } else if ths.windows(2).any(|w| w[0] >= w[1]) {
            out.push(diag(
                Severity::Error,
                "precision",
                format!("feature {f}: encoder thresholds are not strictly ascending"),
            ));
        }
    }

    // Field layout: offsets must be the running sum of per-feature bit
    // widths (the fields are concatenated in feature order).
    let mut offsets = Vec::with_capacity(lut.encoders.len());
    let mut width = 0;
    for enc in &lut.encoders {
        offsets.push(width);
        width += enc.n_bits();
    }
    if lut.offsets != offsets {
        out.push(diag(
            Severity::Error,
            "precision",
            format!(
                "field offsets {:?} disagree with encoder bit widths (expected {:?})",
                lut.offsets, offsets
            ),
        ));
    }

    let classes_ok = lut.classes.len() == lut.stored.len();
    if !classes_ok {
        out.push(diag(
            Severity::Error,
            "schema",
            format!(
                "{} class labels for {} stored rows",
                lut.classes.len(),
                lut.stored.len()
            ),
        ));
    }
    for (r, &c) in lut.classes.iter().enumerate() {
        if c >= lut.n_classes {
            out.push(
                diag(
                    Severity::Error,
                    "class-range",
                    format!("class id {c} out of range (n_classes = {})", lut.n_classes),
                )
                .row(r),
            );
        }
    }

    // Decode every stored row into a RowBox.
    let mut boxes = Vec::with_capacity(lut.stored.len());
    for (r, row) in lut.stored.iter().enumerate() {
        if row.len() != width {
            out.push(
                diag(
                    Severity::Error,
                    "row-encoding",
                    format!("stored row is {} trits wide, fields total {width}", row.len()),
                )
                .row(r),
            );
            continue;
        }
        let mut spans = Vec::with_capacity(lut.encoders.len());
        for (f, enc) in lut.encoders.iter().enumerate() {
            let field = &row[offsets[f]..offsets[f] + enc.n_bits()];
            match decode_field(field) {
                Ok(span) => spans.push(span),
                Err(why) => out.push(
                    diag(Severity::Error, "row-encoding", format!("feature {f}: {why}")).row(r),
                ),
            }
        }
        if spans.len() == lut.encoders.len() {
            let class = if classes_ok { lut.classes[r] } else { 0 };
            boxes.push(RowBox { row: r, class, spans });
        }
    }

    check_against_reduced(bank, lut, &boxes, &offsets, out);
    boxes
}

/// Bijectivity against the reduced rule table: every source path must
/// re-encode to exactly its stored row (span-for-span, class-for-class)
/// and the encoder threshold sets must be exactly the thresholds the
/// paths mention.
fn check_against_reduced(
    bank: usize,
    lut: &Lut,
    boxes: &[RowBox],
    offsets: &[usize],
    out: &mut Vec<Diagnostic>,
) {
    let diag = |sev, check, msg: String| Diagnostic::new(sev, check, msg).bank(bank);
    if lut.reduced.is_empty() {
        if !lut.stored.is_empty() {
            out.push(diag(
                Severity::Info,
                "bijectivity",
                "artifact carries no reduced rule table — path↔row bijectivity not checkable"
                    .to_string(),
            ));
        }
        return;
    }
    if lut.reduced.len() != lut.stored.len() {
        out.push(diag(
            Severity::Error,
            "bijectivity",
            format!(
                "{} source paths but {} stored rows — the mapping cannot be a bijection",
                lut.reduced.len(),
                lut.stored.len()
            ),
        ));
        return;
    }

    let arity_ok = lut.reduced.iter().all(|row| row.rules.len() == lut.encoders.len());
    if !arity_ok {
        out.push(diag(
            Severity::Error,
            "schema",
            format!("reduced rows do not all carry {} rules", lut.encoders.len()),
        ));
        return;
    }

    // The encoder for feature f must be built from exactly the
    // thresholds the paths mention (paper: n_i = T_i + 1 bits).
    for (f, enc) in lut.encoders.iter().enumerate() {
        let rebuilt = FeatureEncoder::from_rules(lut.reduced.iter().map(|row| &row.rules[f]));
        if &rebuilt != enc {
            out.push(diag(
                Severity::Error,
                "precision",
                format!(
                    "feature {f}: encoder thresholds {:?} disagree with the rule table's \
                     threshold set {:?}",
                    enc.thresholds(),
                    rebuilt.thresholds()
                ),
            ));
        }
    }

    // Index decoded boxes by original row for the span comparison.
    let mut box_of = vec![None; lut.stored.len()];
    for b in boxes {
        box_of[b.row] = Some(b);
    }

    for (r, path) in lut.reduced.iter().enumerate() {
        if lut.classes.get(r).copied() != Some(path.class) {
            out.push(
                diag(
                    Severity::Error,
                    "bijectivity",
                    format!(
                        "row class {:?} disagrees with its source path's class {}",
                        lut.classes.get(r),
                        path.class
                    ),
                )
                .row(r),
            );
        }
        let Some(rb) = box_of[r] else { continue };
        for (f, rule) in path.rules.iter().enumerate() {
            if let Some(why) = rule_shape_error(rule) {
                out.push(
                    diag(Severity::Error, "precision", format!("feature {f}: {why}")).row(r),
                );
                continue;
            }
            let enc = &lut.encoders[f];
            match rule_span(enc, rule) {
                Err(why) => out.push(
                    diag(Severity::Error, "precision", format!("feature {f}: {why}")).row(r),
                ),
                Ok(expect) if expect != rb.spans[f] => {
                    let field = &lut.stored[r][offsets[f]..offsets[f] + enc.n_bits()];
                    out.push(
                        diag(
                            Severity::Error,
                            "bijectivity",
                            format!(
                                "feature {f}: path encodes as {:?}, row stores {:?}",
                                span_field_string(expect.0, expect.1, enc.n_bits()),
                                trits_to_string(field),
                            ),
                        )
                        .row(r)
                        .witness(format!(
                            "path covers {}, row covers {}",
                            span_interval(enc, expect.0, expect.1),
                            span_interval(enc, rb.spans[f].0, rb.spans[f].1)
                        )),
                    );
                }
                Ok(_) => {}
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::Dt2Cam;

    fn trits(s: &str) -> Vec<Trit> {
        s.chars()
            .map(|c| match c {
                '0' => Trit::Zero,
                '1' => Trit::One,
                'x' => Trit::X,
                other => panic!("bad trit char {other}"),
            })
            .collect()
    }

    #[test]
    fn decode_field_inverts_the_unary_shape() {
        // 5-bit field: spans (lb, ub) and their canonical shapes.
        assert_eq!(decode_field(&trits("00001")), Ok((0, 0)));
        assert_eq!(decode_field(&trits("11111")), Ok((4, 4)));
        assert_eq!(decode_field(&trits("0xx11")), Ok((1, 3)));
        assert_eq!(decode_field(&trits("xxxx1")), Ok((0, 4)));
        assert_eq!(decode_field(&trits("1")), Ok((0, 0)));
    }

    #[test]
    fn decode_field_rejects_malformed_shapes() {
        assert!(decode_field(&trits("00000")).is_err()); // no trailing one
        assert!(decode_field(&trits("10001")).is_err()); // one before zero
        assert!(decode_field(&trits("00x0x1")).is_err()); // zero inside x-run
        assert!(decode_field(&trits("011x1")).is_err()); // x inside one-run
    }

    #[test]
    fn span_round_trips_through_field_string() {
        for n in 1..7usize {
            for lb in 0..n {
                for ub in lb..n {
                    let s = span_field_string(lb, ub, n);
                    assert_eq!(decode_field(&trits(&s)), Ok((lb, ub)), "n={n} lb={lb} ub={ub}");
                }
            }
        }
    }

    #[test]
    fn compiled_lut_rows_all_decode() {
        let program = Dt2Cam::dataset("iris").unwrap().compile();
        let lut = program.lut();
        let mut diags = Vec::new();
        let boxes = check_rows(0, lut, &mut diags);
        assert!(diags.iter().all(|d| d.severity == Severity::Info), "{diags:?}");
        assert_eq!(boxes.len(), lut.n_rows());
    }

    #[test]
    fn flipped_trit_breaks_bijectivity() {
        let mut program = Dt2Cam::dataset("iris").unwrap().compile();
        let lut = &mut program.banks[0].lut;
        // Turn the last trit of row 0 into a different trit; every
        // rewrite either breaks the field shape or moves the span.
        let last = lut.stored[0].len() - 1;
        lut.stored[0][last] = match lut.stored[0][last] {
            Trit::One => Trit::Zero,
            _ => Trit::One,
        };
        let mut diags = Vec::new();
        check_rows(0, &program.banks[0].lut, &mut diags);
        assert!(
            diags
                .iter()
                .any(|d| d.severity == Severity::Error
                    && (d.check == "bijectivity" || d.check == "row-encoding")),
            "{diags:?}"
        );
    }

    #[test]
    fn foreign_threshold_is_a_precision_error() {
        let mut program = Dt2Cam::dataset("iris").unwrap().compile();
        let lut = &mut program.banks[0].lut;
        // Nudge one finite rule threshold off the encoder's set.
        'outer: for row in &mut lut.reduced {
            for rule in &mut row.rules {
                if rule.th1.is_finite() {
                    rule.th1 += 1e30;
                    break 'outer;
                }
            }
        }
        let mut diags = Vec::new();
        check_rows(0, &program.banks[0].lut, &mut diags);
        assert!(diags.iter().any(|d| d.check == "precision"), "{diags:?}");
    }
}
