//! Pipelined mode (paper Table VI "P" rows, Fig 4) — a *streaming*
//! stage pipeline, composable with the bank model.
//!
//! One worker thread per column division, connected by bounded channels:
//! batch k can be in division d+1 while batch k+1 is in division d —
//! exactly the hardware's pipelining of column-wise tiles. The *modeled*
//! pipelined throughput is `f_max / 3` independent of N_cwd (Table VI:
//! 333 M dec/s at S=128); this module implements the software analogue
//! and the serving coordinator measures its wall-clock scaling against
//! the sequential walk.
//!
//! [`StreamingPipeline`] is the live form: one stage pipeline **per CAM
//! bank** of a program, all banks draining into a single outcome
//! channel, so a multi-bank forest program pipelines every bank
//! concurrently while batches stream through each bank's divisions.
//! [`Coordinator::with_banks_pipelined`](super::Coordinator) feeds
//! admitted batches into the heads and routes [`PipeOutcome`]s back by
//! batch sequence number — this is what `dt2cam serve --pipelined`
//! (with or without `--listen`/`--forest`) runs on. [`run_pipeline`] is
//! the one-shot convenience over a single bank (benches, tests).
//!
//! Stage evaluation goes through the shared [`MatchBackend`] seam — the
//! same kernels as the sequential scheduler and the same survivor
//! readout ([`read_survivors`](super::scheduler)), so pipelined and
//! sequential outcomes are identical by construction. Because stages
//! run on their own threads the backend must be `Send + Sync` (`native`
//! / `threaded-native`; the PJRT client is `Rc`-backed and cannot cross
//! threads — [`crate::api::registry::create_pipeline_backend`] enforces
//! this at the seam).
//!
//! A failing stage poisons **only its own batch**: the error is typed
//! ([`StageError`] — stage index, division id, bank) and travels with
//! the batch to the collector, while later batches keep flowing through
//! the same stages. Nothing in flight is ever silently dropped.

use std::sync::mpsc::{channel, sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::{Arc, OnceLock};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::api::backend::{DivisionMatches, DivisionRequest, MatchBackend};
use crate::obs::{SpanKind, Tracer};
use crate::util::rowmask::RowMask;

use super::plan::ServingPlan;
use super::scheduler::read_survivors;

/// How long collectors wait for the next in-flight outcome before
/// declaring the pipeline stalled (a stage thread can only stop
/// producing if it panicked out from under its channel).
pub const PIPELINE_DRAIN_TIMEOUT: Duration = Duration::from_secs(60);

/// Typed failure of one pipeline stage. Carries *where* the failure
/// happened — the stage index within its bank's pipeline, the column
/// division that stage evaluates, and the bank — so a wire client or a
/// log line can name the failing hardware stage, not just "an error".
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StageError {
    /// Index of the failing stage thread within its bank's pipeline.
    pub stage: usize,
    /// Column division that stage was evaluating (== `stage` for the
    /// division pipeline; kept separate so the identity is explicit at
    /// every use site).
    pub division: usize,
    /// CAM bank whose pipeline the stage belongs to.
    pub bank: usize,
    /// The backend's error, rendered.
    pub message: String,
}

impl std::fmt::Display for StageError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "pipeline stage {} (bank {}, division {}) failed: {}",
            self.stage, self.bank, self.division, self.message
        )
    }
}

impl std::error::Error for StageError {}

/// A batch travelling through one bank's pipeline.
struct PipeBatch {
    seq: u64,
    /// Per-lane padded query bits.
    queries: Vec<Vec<bool>>,
    real_lanes: usize,
    /// Per-lane packed enable mask over padded rows.
    enabled: Vec<RowMask>,
    /// Per-stage match output scratch — travels with the batch, so each
    /// stage reuses the previous stage's allocation.
    matches: DivisionMatches,
    /// Modeled active-row evaluations accumulated so far.
    active_rows: u64,
    /// First stage failure, if any (the batch passes through untouched
    /// afterwards and surfaces the error in its outcome).
    error: Option<StageError>,
    /// Representative trace id for the batch (0 = untraced); stage
    /// threads stamp their spans with it.
    trace: u64,
}

/// Result of one pipelined batch for one bank. Mirrors the sequential
/// [`BatchOutcome`](super::scheduler::BatchOutcome) fields the
/// coordinator rolls up, plus the typed per-batch stage error.
#[derive(Clone, Debug)]
pub struct PipeOutcome {
    /// CAM bank this outcome belongs to (0 for single-bank programs).
    pub bank: usize,
    /// Batch sequence number (as fed).
    pub seq: u64,
    pub classes: Vec<Option<usize>>,
    pub active_row_evals: u64,
    /// Modeled energy of this bank's batch (J) — same closed form as the
    /// sequential scheduler, so roll-ups are bit-identical.
    pub modeled_energy: f64,
    pub no_match: usize,
    pub multi_match: usize,
    /// Set when a stage failed this batch; `classes` is all-`None` then.
    pub error: Option<StageError>,
}

/// Stage worker: evaluate one division for a batch through the backend,
/// folding the matches into the selective-precharge masks.
fn run_stage(
    plan: &ServingPlan,
    backend: &dyn MatchBackend,
    d: usize,
    batch: &mut PipeBatch,
) -> Result<()> {
    // Modeled energy: active rows of real lanes pay this division
    // (popcount per lane).
    for m in batch.enabled.iter().take(batch.real_lanes) {
        batch.active_rows += m.count_ones() as u64;
    }
    // Hardware gating: when no real lane has a surviving row, nothing
    // precharges — this stage (and every later one) is free.
    if batch.enabled[..batch.real_lanes].iter().all(|m| !m.any()) {
        return Ok(());
    }
    let req = DivisionRequest {
        division: d,
        queries: &batch.queries,
        enabled: &batch.enabled,
    };
    backend.match_division(plan, &req, &mut batch.matches)?;
    // Fold: word-wise AND of match bits into the enable masks.
    for (en, m) in batch.enabled.iter_mut().zip(&batch.matches) {
        en.and_assign(m);
    }
    Ok(())
}

/// A live streaming pipeline: one stage pipeline per bank plan, every
/// stage on its own thread, all banks draining into one outcome
/// channel. Feed batches with [`StreamingPipeline::feed`] (blocking
/// send = natural backpressure when the bounded stage channels fill),
/// collect with [`StreamingPipeline::try_next`] /
/// [`StreamingPipeline::next_timeout`]. Outcomes arrive per *(bank,
/// seq)* pair, in each bank's feed order but interleaved across banks.
///
/// Dropping the pipeline closes the heads, lets every in-flight batch
/// drain forward, and joins the stage threads.
pub struct StreamingPipeline {
    heads: Vec<SyncSender<PipeBatch>>,
    out_rx: Receiver<PipeOutcome>,
    threads: Vec<JoinHandle<()>>,
    plans: Vec<Arc<ServingPlan>>,
}

impl StreamingPipeline {
    /// Spawn the stage threads: `plans[b]` gets `plans[b].n_cwd` stage
    /// workers plus one collector, chained by bounded channels of
    /// `depth` batches (>= 1).
    pub fn new(
        plans: Vec<Arc<ServingPlan>>,
        backend: Arc<dyn MatchBackend + Send + Sync>,
        depth: usize,
    ) -> StreamingPipeline {
        Self::with_tracer(plans, backend, depth, Arc::new(OnceLock::new()))
    }

    /// [`StreamingPipeline::new`] with a shared tracer slot: once a
    /// [`Tracer`] lands in the slot (the coordinator attaches it after
    /// construction), every stage thread records one
    /// [`SpanKind::Stage`] span per traced batch it evaluates.
    pub fn with_tracer(
        plans: Vec<Arc<ServingPlan>>,
        backend: Arc<dyn MatchBackend + Send + Sync>,
        depth: usize,
        tracer: Arc<OnceLock<Tracer>>,
    ) -> StreamingPipeline {
        let depth = depth.max(1);
        // The outcome channel is unbounded on purpose: collectors never
        // block, so the pipeline always drains forward and a blocking
        // `feed` can only ever be waiting on stage-0 capacity — no
        // feeder/collector deadlock is constructible.
        let (out_tx, out_rx) = channel::<PipeOutcome>();
        let mut heads = Vec::with_capacity(plans.len());
        let mut threads = Vec::new();
        for (bank, plan) in plans.iter().enumerate() {
            let (head, mut prev_rx) = sync_channel::<PipeBatch>(depth);
            heads.push(head);
            for d in 0..plan.n_cwd {
                let (tx_next, rx_next) = sync_channel::<PipeBatch>(depth);
                let plan = Arc::clone(plan);
                let backend = Arc::clone(&backend);
                let tracer = Arc::clone(&tracer);
                let rx = prev_rx;
                let handle = std::thread::Builder::new()
                    .name(format!("dt2cam-pipe-b{bank}-s{d}"))
                    .spawn(move || {
                        for mut batch in rx {
                            // An already-poisoned batch passes through
                            // untouched; later batches still evaluate.
                            if batch.error.is_none() {
                                let tr = if batch.trace != 0 { tracer.get() } else { None };
                                let s = tr.map(|t| t.now_ns());
                                if let Err(e) = run_stage(&plan, backend.as_ref(), d, &mut batch) {
                                    batch.error = Some(StageError {
                                        stage: d,
                                        division: d,
                                        bank,
                                        message: format!("{e:#}"),
                                    });
                                }
                                if let (Some(t), Some(s)) = (tr, s) {
                                    t.record(
                                        batch.trace,
                                        SpanKind::Stage,
                                        Some(bank),
                                        Some(d),
                                        s,
                                        t.now_ns().saturating_sub(s),
                                    );
                                }
                            }
                            if tx_next.send(batch).is_err() {
                                return;
                            }
                        }
                    })
                    .expect("spawn pipeline stage thread");
                threads.push(handle);
                prev_rx = rx_next;
            }
            // Collector: survivors → classes with the *same* readout as
            // the sequential scheduler, plus the closed-form energy.
            let plan = Arc::clone(plan);
            let out_tx = out_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("dt2cam-pipe-b{bank}-out"))
                .spawn(move || {
                    for batch in prev_rx {
                        // A poisoned batch reads out as all-`None` with
                        // zeroed counters: its masks were folded only
                        // through the divisions before the failure, so
                        // a survivor readout would produce plausible-
                        // looking garbage classes. The typed error is
                        // the batch's whole result.
                        let outcome = if batch.error.is_some() {
                            PipeOutcome {
                                bank,
                                seq: batch.seq,
                                classes: vec![None; batch.queries.len()],
                                active_row_evals: 0,
                                modeled_energy: 0.0,
                                no_match: 0,
                                multi_match: 0,
                                error: batch.error,
                            }
                        } else {
                            let (classes, no_match, multi_match) =
                                read_survivors(&plan, &batch.enabled, batch.real_lanes);
                            let modeled_energy = batch.active_rows as f64 * plan.e_row
                                + batch.real_lanes as f64 * plan.e_mem;
                            PipeOutcome {
                                bank,
                                seq: batch.seq,
                                classes,
                                active_row_evals: batch.active_rows,
                                modeled_energy,
                                no_match,
                                multi_match,
                                error: batch.error,
                            }
                        };
                        if out_tx.send(outcome).is_err() {
                            return;
                        }
                    }
                })
                .expect("spawn pipeline collector thread");
            threads.push(handle);
        }
        // Only the per-bank collector clones keep the channel open.
        drop(out_tx);
        StreamingPipeline {
            heads,
            out_rx,
            threads,
            plans,
        }
    }

    /// Number of bank pipelines.
    pub fn n_banks(&self) -> usize {
        self.plans.len()
    }

    /// Number of stages (column divisions) in bank `bank`'s pipeline.
    pub fn n_stages(&self, bank: usize) -> usize {
        self.plans[bank].n_stages()
    }

    /// Feed one batch into bank `bank`'s pipeline head. Initializes the
    /// enable masks (rogue rows gated out). Blocks while the head
    /// channel is full — bounded-channel backpressure, never unbounded
    /// buffering. Malformed lane widths are a typed error here, at the
    /// seam, not a panic inside a stage thread.
    pub fn feed(
        &self,
        bank: usize,
        seq: u64,
        queries: Vec<Vec<bool>>,
        real_lanes: usize,
    ) -> Result<()> {
        self.feed_traced(bank, seq, queries, real_lanes, 0)
    }

    /// [`StreamingPipeline::feed`] carrying the batch's representative
    /// trace id (0 = untraced); the stage threads stamp their spans
    /// with it.
    pub fn feed_traced(
        &self,
        bank: usize,
        seq: u64,
        queries: Vec<Vec<bool>>,
        real_lanes: usize,
        trace: u64,
    ) -> Result<()> {
        let plan = &self.plans[bank];
        anyhow::ensure!(
            real_lanes <= queries.len(),
            "bank {bank}: {real_lanes} real lanes exceed {} query lanes",
            queries.len()
        );
        for (lane, q) in queries.iter().enumerate() {
            anyhow::ensure!(
                q.len() == plan.n_cwd * plan.s,
                "bank {bank} lane {lane}: query width {} != n_cwd * S = {}",
                q.len(),
                plan.n_cwd * plan.s
            );
        }
        let enabled: Vec<RowMask> = (0..queries.len()).map(|_| plan.initial_mask()).collect();
        let batch = PipeBatch {
            seq,
            queries,
            real_lanes,
            enabled,
            matches: DivisionMatches::new(),
            active_rows: 0,
            error: None,
            trace,
        };
        if self.heads[bank].send(batch).is_err() {
            bail!("pipeline bank {bank} is no longer accepting batches (stage thread died)");
        }
        Ok(())
    }

    /// Collect one finished outcome without blocking.
    pub fn try_next(&self) -> Option<PipeOutcome> {
        self.out_rx.try_recv().ok()
    }

    /// Collect one finished outcome, waiting up to `timeout`. `Ok(None)`
    /// means nothing finished in time; `Err` means the pipeline died
    /// (a stage thread panicked out from under its channel).
    pub fn next_timeout(&self, timeout: Duration) -> Result<Option<PipeOutcome>> {
        match self.out_rx.recv_timeout(timeout) {
            Ok(o) => Ok(Some(o)),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => {
                bail!("pipeline outcome channel closed (stage thread panicked?)")
            }
        }
    }
}

impl Drop for StreamingPipeline {
    fn drop(&mut self) {
        // Closing the heads cascades hang-ups down every stage chain;
        // the unbounded outcome channel guarantees forward drain, so
        // every thread exits and the joins cannot block.
        self.heads.clear();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// One-shot convenience: run a finite stream of batches through a
/// single bank's division pipeline and return every outcome in stream
/// order. Per-batch stage failures come back as
/// [`PipeOutcome::error`] — batches behind a poisoned one still
/// complete; `Err` is reserved for the pipeline machinery itself dying.
pub fn run_pipeline(
    plan: Arc<ServingPlan>,
    backend: Arc<dyn MatchBackend + Send + Sync>,
    batches: Vec<(Vec<Vec<bool>>, usize)>,
    channel_depth: usize,
) -> Result<Vec<PipeOutcome>> {
    let n_batches = batches.len();
    let pipe = StreamingPipeline::new(vec![plan], backend, channel_depth);
    let mut outcomes = Vec::with_capacity(n_batches);
    for (seq, (queries, real_lanes)) in batches.into_iter().enumerate() {
        pipe.feed(0, seq as u64, queries, real_lanes)?;
        // Opportunistic drain keeps the resident set at ~pipeline depth.
        while let Some(o) = pipe.try_next() {
            outcomes.push(o);
        }
    }
    while outcomes.len() < n_batches {
        match pipe.next_timeout(PIPELINE_DRAIN_TIMEOUT)? {
            Some(o) => outcomes.push(o),
            None => bail!(
                "pipeline produced {} of {n_batches} batch outcomes before stalling",
                outcomes.len()
            ),
        }
    }
    outcomes.sort_by_key(|o| o.seq);
    Ok(outcomes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{NativeBackend, ThreadedNativeBackend};
    use crate::cart::{train, TrainParams};
    use crate::compiler::compile;
    use crate::coordinator::scheduler::Scheduler;
    use crate::dataset::catalog;
    use crate::synth::mapping::MappedArray;
    use crate::tcam::params::DeviceParams;
    use crate::util::prng::Prng;

    fn setup(name: &str) -> (Arc<ServingPlan>, MappedArray, crate::compiler::Lut, DeviceParams) {
        let mut d = catalog::by_name(name, 0xD72CA0).unwrap();
        d.normalize();
        let tree = train(&d.features, &d.labels, d.n_classes, &TrainParams::default());
        let lut = compile(&tree);
        let p = DeviceParams::default();
        let mut rng = Prng::new(3);
        let m = MappedArray::from_lut(&lut, 16, &p, &mut rng);
        let plan = Arc::new(ServingPlan::build(&m, &m.vref, &p));
        (plan, m, lut, p)
    }

    fn batches_for(
        name: &str,
        m: &MappedArray,
        lut: &crate::compiler::Lut,
        n: usize,
        width: usize,
    ) -> Vec<(Vec<Vec<bool>>, usize)> {
        let mut d = catalog::by_name(name, 0xD72CA0).unwrap();
        d.normalize();
        d.features[..n]
            .chunks(width)
            .map(|chunk| {
                let qs: Vec<Vec<bool>> = chunk
                    .iter()
                    .map(|x| m.pad_query(&lut.encode_input(x)))
                    .collect();
                let real = qs.len();
                (qs, real)
            })
            .collect()
    }

    #[test]
    fn pipeline_agrees_with_sequential_scheduler() {
        let (plan, m, lut, p) = setup("haberman");
        assert!(m.n_cwd > 1, "pipeline needs several stages");
        let batches = batches_for("haberman", &m, &lut, 48, 16);

        for backend in [
            Arc::new(NativeBackend::new()) as Arc<dyn MatchBackend + Send + Sync>,
            Arc::new(ThreadedNativeBackend::new(3)),
        ] {
            let piped =
                run_pipeline(Arc::clone(&plan), backend, batches.clone(), 2).unwrap();

            let sched = Scheduler::new(&plan, &p);
            for (i, (qs, real)) in batches.iter().enumerate() {
                let seq = sched.run_batch(&NativeBackend::new(), qs, *real).unwrap();
                assert!(piped[i].error.is_none());
                assert_eq!(piped[i].bank, 0);
                assert_eq!(piped[i].classes, seq.classes, "batch {i}");
                assert_eq!(piped[i].active_row_evals, seq.active_row_evals);
                assert_eq!(piped[i].modeled_energy, seq.modeled_energy, "batch {i}");
                assert_eq!(piped[i].no_match, seq.no_match);
                assert_eq!(piped[i].multi_match, seq.multi_match);
            }
        }
    }

    #[test]
    fn pipeline_handles_empty_stream() {
        let (plan, _, _, _) = setup("iris");
        let out = run_pipeline(plan, Arc::new(NativeBackend::new()), vec![], 1).unwrap();
        assert!(out.is_empty());
    }

    /// A backend that fails exactly one call to one division (the k-th),
    /// delegating everything else to the native simulator. Stage threads
    /// process batches in feed order, so the k-th call to division d is
    /// batch seq k — a deterministic poison for one batch of a stream.
    struct PoisonBackend {
        inner: NativeBackend,
        fail_division: usize,
        countdown: std::sync::atomic::AtomicI64,
    }

    impl MatchBackend for PoisonBackend {
        fn name(&self) -> &'static str {
            "poison"
        }
        fn match_division(
            &self,
            plan: &ServingPlan,
            req: &DivisionRequest<'_>,
            out: &mut DivisionMatches,
        ) -> Result<()> {
            use std::sync::atomic::Ordering;
            if req.division == self.fail_division
                && self.countdown.fetch_sub(1, Ordering::SeqCst) == 0
            {
                bail!("injected stage fault");
            }
            self.inner.match_division(plan, req, out)
        }
    }

    #[test]
    fn poisoned_middle_stage_fails_only_its_batch_and_later_batches_complete() {
        let (plan, m, lut, p) = setup("haberman");
        assert!(plan.n_cwd >= 2, "need a middle stage to poison");
        let batches = batches_for("haberman", &m, &lut, 48, 16);
        assert!(batches.len() >= 3);
        let fail_division = 1;
        // countdown = 1: the second call (seq 1) to division 1 fails.
        let backend = Arc::new(PoisonBackend {
            inner: NativeBackend::new(),
            fail_division,
            countdown: std::sync::atomic::AtomicI64::new(1),
        });
        let piped = run_pipeline(Arc::clone(&plan), backend, batches.clone(), 1).unwrap();

        // Nothing in flight was dropped: every batch has an outcome.
        assert_eq!(piped.len(), batches.len());

        // The poisoned batch carries the typed error, naming stage,
        // division and bank...
        let err = piped[1].error.as_ref().expect("batch 1 must fail");
        assert_eq!(err.stage, fail_division);
        assert_eq!(err.division, fail_division);
        assert_eq!(err.bank, 0);
        assert!(err.message.contains("injected stage fault"), "{err}");
        let shown = err.to_string();
        assert!(
            shown.contains("stage 1") && shown.contains("division 1"),
            "display must name the failing stage: {shown}"
        );
        // ...and no plausible-looking classes from the partial fold: a
        // caller that forgets to check `error` sees all-None, never a
        // silent misclassification.
        assert!(piped[1].classes.iter().all(|c| c.is_none()));
        assert_eq!(piped[1].active_row_evals, 0);
        assert_eq!(piped[1].modeled_energy, 0.0);

        // ...while every other batch completes with sequential-identical
        // classes (the poisoned batch skipped later stages untouched).
        let sched = Scheduler::new(&plan, &p);
        for (i, (qs, real)) in batches.iter().enumerate() {
            if i == 1 {
                continue;
            }
            let seq = sched.run_batch(&NativeBackend::new(), qs, *real).unwrap();
            assert!(piped[i].error.is_none(), "batch {i} must succeed");
            assert_eq!(piped[i].classes, seq.classes, "batch {i}");
            assert_eq!(piped[i].active_row_evals, seq.active_row_evals);
        }
    }

    #[test]
    fn traced_batches_record_one_stage_span_per_division() {
        let (plan, m, lut, _p) = setup("haberman");
        assert!(plan.n_cwd >= 2);
        let slot: Arc<OnceLock<Tracer>> = Arc::new(OnceLock::new());
        let tracer = Tracer::new(1);
        assert!(slot.set(tracer.clone()).is_ok());
        let pipe = StreamingPipeline::with_tracer(
            vec![Arc::clone(&plan)],
            Arc::new(NativeBackend::new()),
            1,
            slot,
        );
        let batches = batches_for("haberman", &m, &lut, 32, 8);
        let n = batches.len();
        assert!(n >= 2);
        for (seq, (qs, real)) in batches.into_iter().enumerate() {
            // Only the first batch is traced — the rest must record
            // nothing.
            let trace = if seq == 0 { 42 } else { 0 };
            pipe.feed_traced(0, seq as u64, qs, real, trace).unwrap();
        }
        let mut got = 0;
        while got < n {
            match pipe.next_timeout(PIPELINE_DRAIN_TIMEOUT).unwrap() {
                Some(_) => got += 1,
                None => panic!("pipeline stalled at {got} outcomes"),
            }
        }
        let spans = tracer.snapshot();
        assert_eq!(spans.len(), plan.n_cwd, "one stage span per division");
        assert!(spans
            .iter()
            .all(|s| s.kind == SpanKind::Stage && s.trace == 42 && s.bank == 0));
        let mut divs: Vec<u32> = spans.iter().map(|s| s.division).collect();
        divs.sort_unstable();
        assert_eq!(divs, (0..plan.n_cwd as u32).collect::<Vec<_>>());
    }

    #[test]
    fn feed_rejects_malformed_lane_width_with_typed_error() {
        let (plan, _, _, _) = setup("iris");
        let pipe = StreamingPipeline::new(
            vec![Arc::clone(&plan)],
            Arc::new(NativeBackend::new()),
            1,
        );
        let err = pipe
            .feed(0, 0, vec![vec![false; 3]], 1)
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("width") && msg.contains("bank 0"), "{msg}");
        // A real-lane overrun is typed too.
        let err = pipe
            .feed(0, 0, vec![vec![false; plan.n_cwd * plan.s]], 2)
            .unwrap_err();
        assert!(format!("{err:#}").contains("real lanes"));
    }

    #[test]
    fn streaming_pipeline_runs_banks_concurrently_and_tags_outcomes() {
        // Two banks (same plan twice is fine — the pipeline is
        // bank-agnostic), distinct batch streams per bank: every
        // outcome must come back tagged with its (bank, seq) and equal
        // the sequential walk of that bank's stream.
        let (plan, m, lut, p) = setup("haberman");
        let pipe = StreamingPipeline::new(
            vec![Arc::clone(&plan), Arc::clone(&plan)],
            Arc::new(NativeBackend::new()),
            2,
        );
        assert_eq!(pipe.n_banks(), 2);
        assert_eq!(pipe.n_stages(0), plan.n_cwd);
        let streams = [
            batches_for("haberman", &m, &lut, 32, 8),
            batches_for("haberman", &m, &lut, 48, 16),
        ];
        let mut expected_outcomes = 0;
        for (b, stream) in streams.iter().enumerate() {
            for (seq, (qs, real)) in stream.iter().enumerate() {
                pipe.feed(b, seq as u64, qs.clone(), *real).unwrap();
                expected_outcomes += 1;
            }
        }
        let mut got: Vec<PipeOutcome> = Vec::new();
        while got.len() < expected_outcomes {
            match pipe.next_timeout(PIPELINE_DRAIN_TIMEOUT).unwrap() {
                Some(o) => got.push(o),
                None => panic!("pipeline stalled at {} outcomes", got.len()),
            }
        }
        let sched = Scheduler::new(&plan, &p);
        for o in &got {
            let (qs, real) = &streams[o.bank][o.seq as usize];
            let seq = sched.run_batch(&NativeBackend::new(), qs, *real).unwrap();
            assert!(o.error.is_none());
            assert_eq!(o.classes, seq.classes, "bank {} seq {}", o.bank, o.seq);
            assert_eq!(o.active_row_evals, seq.active_row_evals);
        }
        // Each (bank, seq) pair arrived exactly once.
        let mut keys: Vec<(usize, u64)> = got.iter().map(|o| (o.bank, o.seq)).collect();
        keys.sort_unstable();
        keys.dedup();
        assert_eq!(keys.len(), expected_outcomes, "duplicate or lost outcomes");
    }
}
