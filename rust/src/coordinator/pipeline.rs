//! Pipelined mode (paper Table VI "P" rows, Fig 4).
//!
//! One worker thread per column division, connected by bounded channels:
//! batch k can be in division d+1 while batch k+1 is in division d —
//! exactly the hardware's pipelining of column-wise tiles. The *modeled*
//! pipelined throughput is `f_max / 3` independent of N_cwd (Table VI:
//! 333 M dec/s at S=128); this module demonstrates the software analogue
//! and measures its wall-clock scaling against the sequential walk.
//!
//! Stage evaluation goes through the shared [`MatchBackend`] seam — the
//! same kernels as the sequential scheduler, so pipelined and sequential
//! outcomes are identical by construction. Because stages run on their
//! own threads the backend must be `Send + Sync` (`native` /
//! `threaded-native`; the PJRT client is `Rc`-backed and cannot cross
//! threads — [`crate::api::registry::create_pipeline_backend`] enforces
//! this at the seam).

use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::api::backend::{DivisionMatches, DivisionRequest, MatchBackend};
use crate::util::rowmask::RowMask;

use super::plan::ServingPlan;

/// A batch travelling through the pipeline.
struct PipeBatch {
    seq: u64,
    /// Per-lane padded query bits.
    queries: Vec<Vec<bool>>,
    real_lanes: usize,
    /// Per-lane packed enable mask over padded rows.
    enabled: Vec<RowMask>,
    /// Per-stage match output scratch — travels with the batch, so each
    /// stage reuses the previous stage's allocation.
    matches: DivisionMatches,
    /// Modeled active-row evaluations accumulated so far.
    active_rows: u64,
    /// First stage error, if any (batch passes through untouched after).
    error: Option<String>,
}

/// Result of one pipelined batch.
#[derive(Clone, Debug)]
pub struct PipeOutcome {
    pub seq: u64,
    pub classes: Vec<Option<usize>>,
    pub active_row_evals: u64,
    pub no_match: usize,
    pub multi_match: usize,
}

/// Stage worker: evaluate one division for a batch through the backend,
/// folding the matches into the selective-precharge masks.
fn run_stage(
    plan: &ServingPlan,
    backend: &dyn MatchBackend,
    d: usize,
    batch: &mut PipeBatch,
) -> Result<()> {
    // Modeled energy: active rows of real lanes pay this division
    // (popcount per lane).
    for m in batch.enabled.iter().take(batch.real_lanes) {
        batch.active_rows += m.count_ones() as u64;
    }
    // Hardware gating: when no real lane has a surviving row, nothing
    // precharges — this stage (and every later one) is free.
    if batch.enabled[..batch.real_lanes].iter().all(|m| !m.any()) {
        return Ok(());
    }
    let req = DivisionRequest {
        division: d,
        queries: &batch.queries,
        enabled: &batch.enabled,
    };
    backend.match_division(plan, &req, &mut batch.matches)?;
    // Fold: word-wise AND of match bits into the enable masks.
    for (en, m) in batch.enabled.iter_mut().zip(&batch.matches) {
        en.and_assign(m);
    }
    Ok(())
}

/// Run a stream of batches through the division pipeline. Returns
/// outcomes in stream order.
pub fn run_pipeline(
    plan: Arc<ServingPlan>,
    backend: Arc<dyn MatchBackend + Send + Sync>,
    batches: Vec<(Vec<Vec<bool>>, usize)>,
    channel_depth: usize,
) -> Result<Vec<PipeOutcome>> {
    let n_stages = plan.n_cwd;
    let n_batches = batches.len();

    // Stage 0 input channel.
    let (tx0, rx0): (SyncSender<PipeBatch>, Receiver<PipeBatch>) =
        sync_channel(channel_depth.max(1));

    let mut handles = Vec::new();
    let mut prev_rx = rx0;
    for d in 0..n_stages {
        let (tx_next, rx_next) = sync_channel::<PipeBatch>(channel_depth.max(1));
        let plan = Arc::clone(&plan);
        let backend = Arc::clone(&backend);
        let rx = prev_rx;
        handles.push(std::thread::spawn(move || {
            for mut batch in rx {
                if batch.error.is_none() {
                    if let Err(e) = run_stage(&plan, backend.as_ref(), d, &mut batch) {
                        batch.error = Some(format!("{e:#}"));
                    }
                }
                if tx_next.send(batch).is_err() {
                    return;
                }
            }
        }));
        prev_rx = rx_next;
    }

    // Feeder: initializes the enable masks (rogue rows gated out).
    let feeder = {
        let plan = Arc::clone(&plan);
        std::thread::spawn(move || {
            for (seq, (queries, real_lanes)) in batches.into_iter().enumerate() {
                let lanes = queries.len();
                let enabled: Vec<RowMask> =
                    (0..lanes).map(|_| plan.initial_mask()).collect();
                let batch = PipeBatch {
                    seq: seq as u64,
                    enabled,
                    queries,
                    real_lanes,
                    matches: DivisionMatches::new(),
                    active_rows: 0,
                    error: None,
                };
                if tx0.send(batch).is_err() {
                    return;
                }
            }
        })
    };

    // Collector (this thread).
    let mut outcomes = Vec::with_capacity(n_batches);
    let mut first_error: Option<String> = None;
    for mut batch in prev_rx {
        if let Some(e) = batch.error.take() {
            first_error.get_or_insert(e);
        }
        let mut classes = Vec::with_capacity(batch.queries.len());
        let mut no_match = 0;
        let mut multi_match = 0;
        for (lane, en) in batch.enabled.iter().enumerate() {
            if lane >= batch.real_lanes {
                classes.push(None);
                continue;
            }
            let mut survivors = en.ones();
            match (survivors.next(), survivors.next()) {
                (None, _) => {
                    no_match += 1;
                    classes.push(None);
                }
                (Some(first), None) => classes.push(Some(plan.classes[first])),
                (Some(first), Some(_)) => {
                    multi_match += 1;
                    classes.push(Some(plan.classes[first]));
                }
            }
        }
        outcomes.push(PipeOutcome {
            seq: batch.seq,
            classes,
            active_row_evals: batch.active_rows,
            no_match,
            multi_match,
        });
        batch.enabled.clear();
        if outcomes.len() == n_batches {
            break;
        }
    }
    // A panicking stage (e.g. malformed query width) drops its batch and
    // closes the downstream channel — joins must surface that instead of
    // returning Ok with silently truncated outcomes.
    if feeder.join().is_err() {
        bail!("pipeline feeder thread panicked");
    }
    let mut panicked = false;
    for h in handles {
        panicked |= h.join().is_err();
    }
    if panicked {
        bail!("pipeline stage thread panicked (malformed batch input?)");
    }
    if let Some(e) = first_error {
        bail!("pipeline stage failed: {e}");
    }
    if outcomes.len() != n_batches {
        bail!(
            "pipeline produced {} of {} batch outcomes",
            outcomes.len(),
            n_batches
        );
    }
    outcomes.sort_by_key(|o| o.seq);
    Ok(outcomes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{NativeBackend, ThreadedNativeBackend};
    use crate::cart::{train, TrainParams};
    use crate::compiler::compile;
    use crate::coordinator::scheduler::Scheduler;
    use crate::dataset::catalog;
    use crate::synth::mapping::MappedArray;
    use crate::tcam::params::DeviceParams;
    use crate::util::prng::Prng;

    #[test]
    fn pipeline_agrees_with_sequential_scheduler() {
        let mut d = catalog::by_name("haberman", 0xD72CA0).unwrap();
        d.normalize();
        let tree = train(&d.features, &d.labels, d.n_classes, &TrainParams::default());
        let lut = compile(&tree);
        let p = DeviceParams::default();
        let mut rng = Prng::new(3);
        let m = MappedArray::from_lut(&lut, 16, &p, &mut rng);
        assert!(m.n_cwd > 1, "pipeline needs several stages");
        let plan = Arc::new(ServingPlan::build(&m, &m.vref, &p));

        let batches: Vec<(Vec<Vec<bool>>, usize)> = d.features[..48]
            .chunks(16)
            .map(|chunk| {
                let qs: Vec<Vec<bool>> = chunk
                    .iter()
                    .map(|x| m.pad_query(&lut.encode_input(x)))
                    .collect();
                let n = qs.len();
                (qs, n)
            })
            .collect();

        for backend in [
            Arc::new(NativeBackend::new()) as Arc<dyn MatchBackend + Send + Sync>,
            Arc::new(ThreadedNativeBackend::new(3)),
        ] {
            let piped =
                run_pipeline(Arc::clone(&plan), backend, batches.clone(), 2).unwrap();

            let sched = Scheduler::new(&plan, &p);
            for (i, (qs, real)) in batches.iter().enumerate() {
                let seq = sched.run_batch(&NativeBackend::new(), qs, *real).unwrap();
                assert_eq!(piped[i].classes, seq.classes, "batch {i}");
                assert_eq!(piped[i].active_row_evals, seq.active_row_evals);
            }
        }
    }

    #[test]
    fn pipeline_handles_empty_stream() {
        let mut d = catalog::by_name("iris", 0).unwrap();
        d.normalize();
        let tree = train(&d.features, &d.labels, d.n_classes, &TrainParams::default());
        let lut = compile(&tree);
        let p = DeviceParams::default();
        let mut rng = Prng::new(3);
        let m = MappedArray::from_lut(&lut, 16, &p, &mut rng);
        let plan = Arc::new(ServingPlan::build(&m, &m.vref, &p));
        let out = run_pipeline(plan, Arc::new(NativeBackend::new()), vec![], 1).unwrap();
        assert!(out.is_empty());
    }
}
