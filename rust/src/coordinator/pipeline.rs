//! Pipelined mode (paper Table VI "P" rows, Fig 4).
//!
//! One worker thread per column division, connected by bounded channels:
//! batch k can be in division d+1 while batch k+1 is in division d —
//! exactly the hardware's pipelining of column-wise tiles. The *modeled*
//! pipelined throughput is `f_max / 3` independent of N_cwd (Table VI:
//! 333 M dec/s at S=128); this module demonstrates the software analogue
//! and measures its wall-clock scaling against the sequential walk.
//!
//! Native engine only: the PJRT client is single-threaded by construction
//! (`Rc`), so the pipelined request path uses the f32 simulator — same
//! numerics, same plan buffers.

use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::Arc;

use anyhow::Result;

use super::plan::ServingPlan;

/// A batch travelling through the pipeline.
struct PipeBatch {
    seq: u64,
    /// Per-lane padded query bits.
    queries: Vec<Vec<bool>>,
    real_lanes: usize,
    /// Per-lane enable mask over padded rows.
    enabled: Vec<Vec<bool>>,
    /// Modeled active-row evaluations accumulated so far.
    active_rows: u64,
}

/// Result of one pipelined batch.
#[derive(Clone, Debug)]
pub struct PipeOutcome {
    pub seq: u64,
    pub classes: Vec<Option<usize>>,
    pub active_row_evals: u64,
    pub no_match: usize,
    pub multi_match: usize,
}

/// Stage worker: evaluate one division for a batch. Density-adaptive like
/// the sequential scheduler (§Perf): a vectorizable dense gather when most
/// rows are still enabled (stage 0), scalar sparse evaluation afterwards.
fn run_stage(plan: &ServingPlan, d: usize, batch: &mut PipeBatch) {
    let s = plan.s;
    let div = &plan.divisions[d];
    let col0 = d * s;
    let mut g_dense = vec![0.0f32; s];
    for lane in 0..batch.queries.len() {
        let active = batch.enabled[lane].iter().filter(|&&e| e).count();
        if lane < batch.real_lanes {
            batch.active_rows += active as u64;
        }
        let bits = &batch.queries[lane][col0..col0 + s];
        let en = &mut batch.enabled[lane];
        let dense = active * 8 >= plan.padded_rows;
        for rt in 0..plan.n_rwd {
            let w_tile = &div.w[rt * 2 * s * s..(rt + 1) * 2 * s * s];
            let gthresh_tile = &div.gthresh[rt * s..(rt + 1) * s];
            if dense {
                g_dense.iter_mut().for_each(|x| *x = 0.0);
                for (j, &b) in bits.iter().enumerate() {
                    let row_w = &w_tile
                        [(2 * j + usize::from(b)) * s..(2 * j + usize::from(b) + 1) * s];
                    for (acc, &wv) in g_dense.iter_mut().zip(row_w) {
                        *acc += wv;
                    }
                }
                for r in 0..s {
                    let idx = rt * s + r;
                    // Log-domain SA compare (§Perf): no exp per row.
                    en[idx] = en[idx] && g_dense[r] < gthresh_tile[r];
                }
            } else {
                // Selective precharge: only still-enabled rows evaluate.
                for r in 0..s {
                    let idx = rt * s + r;
                    if !en[idx] {
                        continue;
                    }
                    let mut g = 0.0f32;
                    for (j, &b) in bits.iter().enumerate() {
                        g += w_tile[(2 * j + usize::from(b)) * s + r];
                    }
                    en[idx] = g < gthresh_tile[r];
                }
            }
        }
    }
}

/// Run a stream of batches through the division pipeline. Returns
/// outcomes in stream order.
pub fn run_pipeline(
    plan: Arc<ServingPlan>,
    batches: Vec<(Vec<Vec<bool>>, usize)>,
    channel_depth: usize,
) -> Result<Vec<PipeOutcome>> {
    let n_stages = plan.n_cwd;
    let n_batches = batches.len();

    // Stage 0 input channel.
    let (tx0, rx0): (SyncSender<PipeBatch>, Receiver<PipeBatch>) =
        sync_channel(channel_depth.max(1));

    let mut handles = Vec::new();
    let mut prev_rx = rx0;
    for d in 0..n_stages {
        let (tx_next, rx_next) = sync_channel::<PipeBatch>(channel_depth.max(1));
        let plan = Arc::clone(&plan);
        let rx = prev_rx;
        handles.push(std::thread::spawn(move || {
            for mut batch in rx {
                run_stage(&plan, d, &mut batch);
                if tx_next.send(batch).is_err() {
                    return;
                }
            }
        }));
        prev_rx = rx_next;
    }

    // Feeder: initializes the enable masks (rogue rows gated out).
    let feeder = {
        let plan = Arc::clone(&plan);
        std::thread::spawn(move || {
            for (seq, (queries, real_lanes)) in batches.into_iter().enumerate() {
                let lanes = queries.len();
                let enabled: Vec<Vec<bool>> = (0..lanes)
                    .map(|_| {
                        let mut v = vec![false; plan.padded_rows];
                        v[..plan.initially_active].fill(true);
                        v
                    })
                    .collect();
                let batch = PipeBatch {
                    seq: seq as u64,
                    enabled,
                    queries,
                    real_lanes,
                    active_rows: 0,
                };
                if tx0.send(batch).is_err() {
                    return;
                }
            }
        })
    };

    // Collector (this thread).
    let mut outcomes = Vec::with_capacity(n_batches);
    for mut batch in prev_rx {
        let mut classes = Vec::with_capacity(batch.queries.len());
        let mut no_match = 0;
        let mut multi_match = 0;
        for (lane, en) in batch.enabled.iter().enumerate() {
            if lane >= batch.real_lanes {
                classes.push(None);
                continue;
            }
            let mut survivors = en.iter().enumerate().filter(|(_, &e)| e).map(|(i, _)| i);
            match (survivors.next(), survivors.next()) {
                (None, _) => {
                    no_match += 1;
                    classes.push(None);
                }
                (Some(first), None) => classes.push(Some(plan.classes[first])),
                (Some(first), Some(_)) => {
                    multi_match += 1;
                    classes.push(Some(plan.classes[first]));
                }
            }
        }
        outcomes.push(PipeOutcome {
            seq: batch.seq,
            classes,
            active_row_evals: batch.active_rows,
            no_match,
            multi_match,
        });
        batch.enabled.clear();
        if outcomes.len() == n_batches {
            break;
        }
    }
    feeder.join().ok();
    for h in handles {
        h.join().ok();
    }
    outcomes.sort_by_key(|o| o.seq);
    Ok(outcomes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cart::{train, TrainParams};
    use crate::compiler::compile;
    use crate::coordinator::scheduler::{EngineRef, Scheduler};
    use crate::dataset::catalog;
    use crate::synth::mapping::MappedArray;
    use crate::tcam::params::DeviceParams;
    use crate::util::prng::Prng;

    #[test]
    fn pipeline_agrees_with_sequential_scheduler() {
        let mut d = catalog::by_name("haberman", 0xD72CA0).unwrap();
        d.normalize();
        let tree = train(&d.features, &d.labels, d.n_classes, &TrainParams::default());
        let lut = compile(&tree);
        let p = DeviceParams::default();
        let mut rng = Prng::new(3);
        let m = MappedArray::from_lut(&lut, 16, &p, &mut rng);
        assert!(m.n_cwd > 1, "pipeline needs several stages");
        let plan = Arc::new(ServingPlan::build(&m, &m.vref, &p));

        let batches: Vec<(Vec<Vec<bool>>, usize)> = d.features[..48]
            .chunks(16)
            .map(|chunk| {
                let qs: Vec<Vec<bool>> = chunk
                    .iter()
                    .map(|x| m.pad_query(&lut.encode_input(x)))
                    .collect();
                let n = qs.len();
                (qs, n)
            })
            .collect();

        let piped = run_pipeline(Arc::clone(&plan), batches.clone(), 2).unwrap();

        let sched = Scheduler::new(&plan, &p);
        for (i, (qs, real)) in batches.iter().enumerate() {
            let seq = sched.run_batch(&EngineRef::Native, qs, *real).unwrap();
            assert_eq!(piped[i].classes, seq.classes, "batch {i}");
            assert_eq!(piped[i].active_row_evals, seq.active_row_evals);
        }
    }

    #[test]
    fn pipeline_handles_empty_stream() {
        let mut d = catalog::by_name("iris", 0).unwrap();
        d.normalize();
        let tree = train(&d.features, &d.labels, d.n_classes, &TrainParams::default());
        let lut = compile(&tree);
        let p = DeviceParams::default();
        let mut rng = Prng::new(3);
        let m = MappedArray::from_lut(&lut, 16, &p, &mut rng);
        let plan = Arc::new(ServingPlan::build(&m, &m.vref, &p));
        let out = run_pipeline(plan, vec![], 1).unwrap();
        assert!(out.is_empty());
    }
}
