//! The coordinator: ties batcher + scheduler + metrics into a serving
//! loop over the CAM **banks** of one program. A single-tree program is
//! the 1-bank special case; a forest program fans each batch out across
//! its banks (independent CAM arrays — in parallel over a
//! [`ThreadPool`] when the backend is `Send + Sync`, sequentially for
//! the `!Send` PJRT client) and combines the surviving classes with the
//! deterministic majority vote from [`crate::cart::Forest`]. This is
//! the `dt2cam serve` engine, the substance of [`crate::api::Session`],
//! and the heart of the `serve_e2e` / `forest_serve` examples.
//!
//! Two execution strategies share this facade:
//!
//! * **batch-sequential** ([`Coordinator::with_banks`]) — each released
//!   batch walks every division of every bank to completion before the
//!   next batch starts (bank fan-out over the pool, divisions in
//!   order);
//! * **stage-pipelined** ([`Coordinator::with_banks_pipelined`], the
//!   paper's Table VI "P" mode) — each bank owns a live
//!   [`StreamingPipeline`] stage per column division, batches are *fed*
//!   on submit-side polls and *collected* as they emerge, so several
//!   batches are in flight across divisions at once while banks stream
//!   concurrently. Outcomes are re-joined per batch by sequence number
//!   and voted exactly like the sequential path — the two strategies
//!   are bit-identical in classes, energy, and row activity.
//!
//! Hardware cost semantics (see `cart::forest`): modeled energy is the
//! **sum** over banks (every array burns its own joules), modeled
//! latency is the **slowest** bank plus the digital vote stage (banks
//! search concurrently).

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::api::backend::{BankDispatch, MatchBackend, RemoteBankOutcome, RemoteWorkerStatus};
use crate::api::program::MAPPED_FORMAT;
use crate::api::registry::{self, BackendOptions};
use crate::obs::{SpanKind, Tracer};
use crate::cart::vote_survivors;
use crate::compiler::Lut;
use crate::config::RunConfig;
use crate::synth::latency::forest_latency;
use crate::synth::mapping::MappedArray;
use crate::tcam::params::DeviceParams;
use crate::util::threadpool::ThreadPool;

use super::batcher::{BatchKey, Batcher, InferenceRequest};
use super::metrics::Metrics;
use super::pipeline::{PipeOutcome, StreamingPipeline, PIPELINE_DRAIN_TIMEOUT};
use super::plan::ServingPlan;
use super::registry::ProgramRegistry;
use super::scheduler::{BatchOutcome, BatchScratch, Scheduler};

use crate::api::backend::ProgramStamp;

/// Program id every coordinator boots with (the program its
/// constructor was handed). `dt2cam load` adds tenants next to it.
pub const DEFAULT_PROGRAM: &str = "default";

/// Resident-program bound a coordinator starts with
/// (`serve --max-programs` retunes it).
pub const DEFAULT_MAX_PROGRAMS: usize = 4;

/// One answered request.
#[derive(Clone, Debug)]
pub struct InferenceResponse {
    pub id: u64,
    /// Predicted class (None = no surviving row in any bank).
    pub class: Option<usize>,
    /// Modeled per-decision latency of the hardware (s): slowest bank +
    /// vote stage for forest programs, the single bank's latency
    /// otherwise.
    pub modeled_latency: f64,
    /// Set when serving this request's batch failed (a rendered
    /// [`StageError`](super::pipeline::StageError) from the pipelined
    /// mode, or an admission refusal — unknown pin, short feature
    /// vector); `class` carries no information then. The socket server
    /// routes such responses as typed error frames.
    pub error: Option<String>,
    /// Trace id this response answers (copied from the request; 0 =
    /// untraced). The socket server echoes it in the response frame so
    /// clients can correlate answers with exported spans.
    pub trace: u64,
    /// Admission stamp: the program id this request was admitted
    /// against (empty only for refusals of unknown pins).
    pub program: String,
    /// Admission stamp: the program version (0 only for refusals of
    /// unknown pins). In-flight batches finish on the version they were
    /// admitted under even across an `activate` — this stamp is the
    /// proof.
    pub version: u64,
}

/// One row of [`Coordinator::program_list`] — the serving-side truth
/// behind the `Frame::Programs` admin reply.
#[derive(Clone, Debug, PartialEq)]
pub struct ProgramStatus {
    pub id: String,
    pub version: u64,
    pub active: bool,
    /// Whole-program bank count (identity figure, not the local
    /// subset's).
    pub banks: usize,
    /// Whole-program physical rows (identity figure).
    pub rows_physical: u64,
    /// Requests admitted against this program and not yet answered.
    pub in_flight: u64,
}

/// One bank's compiled + mapped pieces handed to
/// [`Coordinator::with_banks`] (borrowed — the coordinator builds its
/// own plan from them).
pub struct BankSpec<'a> {
    /// The bank's compiled LUT (owned; the coordinator keeps it for
    /// input encoding).
    pub lut: Lut,
    /// Feature projection: `features[j]` is the original dataset index
    /// of this bank's j-th feature (identity for single-tree programs).
    pub features: Vec<usize>,
    /// The bank's tile grid.
    pub mapped: &'a MappedArray,
    /// The bank's per-(division, row) reference voltages.
    pub vref: &'a [f64],
    /// Rows the bank's artifact actually stores (logical rows minus
    /// cross-bank shared-copy elisions — see
    /// `CompiledProgram::row_accounting`). Equal to `lut.n_rows()` for
    /// unoptimized programs; only feeds the metrics roll-up.
    pub rows_physical: usize,
}

/// Everything one bank needs on the request path.
struct BankRuntime {
    lut: Lut,
    features: Vec<usize>,
    padded_width: usize,
    /// Shared with the stage-pipeline threads in pipelined mode (an
    /// uncontended refcount bump otherwise).
    plan: Arc<ServingPlan>,
    /// Per-bank scheduler scratch, reused across every batch. Behind a
    /// `Mutex` so the parallel fan-out can reach it through `&self`
    /// (uncontended — exactly one job per bank per batch).
    scratch: Mutex<BatchScratch>,
}

/// One batch in flight inside the stage pipelines: the requests it
/// answers, and the per-bank outcomes collected so far.
struct PendingPipe {
    reqs: Vec<InferenceRequest>,
    /// Indexed by bank; filled as outcomes emerge.
    outcomes: Vec<Option<PipeOutcome>>,
    remaining: usize,
    /// When the batch entered the pipeline (per-batch residence time).
    fed: Instant,
}

/// Streaming pipelined execution state.
struct PipelineState {
    stream: StreamingPipeline,
    /// seq → in-flight batch. Bounded by the stage channels' depth ×
    /// stages (the feed blocks past that), never by client behavior.
    pending: HashMap<u64, PendingPipe>,
    next_seq: u64,
    /// Start of the still-unaccounted slice of the current busy span.
    /// Pipelined batches overlap, so per-batch walls cannot be summed
    /// into `Metrics::wall_total`; instead busy (in-flight) time is
    /// rolled into it incrementally on every poll — the same
    /// no-idle-time convention the sequential path gets by
    /// construction, and live metrics scrapes under sustained load see
    /// a current figure rather than one frozen at the last idle point.
    busy_since: Option<Instant>,
}

/// Everything one resident *program* needs on the request path — the
/// registry payload. One of these per tenant; the active one serves
/// unpinned traffic.
struct ProgramRuntime {
    banks: Vec<BankRuntime>,
    /// Global bank id of each local bank (identity for a coordinator
    /// serving the whole program; a strict ascending subset on a
    /// cluster worker — see [`Coordinator::set_bank_ids`]).
    bank_ids: Vec<usize>,
    n_classes: usize,
    /// Minimum feature-vector length a request for this program must
    /// carry (largest projected original-feature index + 1).
    n_features: usize,
    /// Modeled per-decision latency (slowest bank + vote stage).
    modeled_latency: f64,
    /// Modeled pipelined throughput (0 in batch-sequential mode).
    modeled_pipe_throughput: f64,
    /// Logical rows the banks evaluate / rows their artifacts store.
    rows_total: u64,
    rows_physical: u64,
    /// Program identity advertised over `Frame::Health` and checked
    /// against `Frame::BankBatch` stamps (the format is always
    /// [`MAPPED_FORMAT`]): bank count and physical rows of the *whole*
    /// program. Defaults to the locally served figures; a cluster
    /// worker serving a placement subset overwrites them with the full
    /// program's so every worker advertises one identity.
    program_banks: usize,
    program_rows_physical: u64,
    /// Streaming pipelined execution (None = batch-sequential walk).
    /// Per program: each tenant streams through its own bank stages,
    /// so a swap never flushes another tenant's in-flight batches.
    pipeline: Option<PipelineState>,
}

/// Construction recipe for pipelined coordinators, retained so
/// programs loaded later get their own stage pipelines.
struct PipeConfig {
    backend: Arc<dyn MatchBackend + Send + Sync>,
    depth: usize,
}

/// The serving coordinator. Owns the program registry (one
/// [`ProgramRuntime`] per resident tenant, one active id) and the bank
/// dispatch; single-threaded facade (the PJRT backend is `!Send`), with
/// bank-level fan-out (and row-tile parallelism inside the backend) for
/// `Send + Sync` backends, and an optional streaming stage pipeline per
/// bank ([`Coordinator::with_banks_pipelined`]).
///
/// **Lifecycle semantics** (`load_program` / `activate_program`):
/// admissions stamp `(program id, version)` at submit time and the
/// batcher keys on that stamp, so a batch never mixes programs;
/// activation flips one registry index and only re-routes *future*
/// unpinned submits — in-flight batches finish on the slot they were
/// admitted under, which reload/eviction cannot touch while requests
/// are in flight.
pub struct Coordinator {
    programs: ProgramRegistry<ProgramRuntime>,
    params: DeviceParams,
    dispatch: BankDispatch,
    /// Bank fan-out pool — present only for parallel dispatch over more
    /// than one bank (used for batch execution in sequential mode and
    /// for per-bank query encoding in both modes). Sized for the widest
    /// resident program.
    pool: Option<ThreadPool>,
    /// Worker count the pool was built for (0 = no pool).
    pool_banks: usize,
    batcher: Batcher,
    /// Batch width, retained to warm later-loaded programs identically.
    batch: usize,
    pub metrics: Metrics,
    /// Pipelined construction recipe (None = batch-sequential).
    pipe: Option<PipeConfig>,
    /// Global bank ids this process serves (cluster workers only);
    /// applied to every later-loaded program so a worker's subset is
    /// program-uniform.
    subset: Option<Vec<usize>>,
    /// Tracing slot — empty until the socket server attaches a
    /// [`Tracer`] (`--trace-sample`). A shared `OnceLock` rather than a
    /// plain field so the pipeline stage threads (spawned at
    /// construction, before any attach can happen) observe the
    /// attachment too.
    tracer: Arc<OnceLock<Tracer>>,
    /// Admission refusals (unknown pin, short feature vector) waiting
    /// for the next poll — they flow out as typed error responses
    /// through the same channel as served answers.
    rejects: Vec<InferenceResponse>,
}

impl Coordinator {
    /// Build a single-bank coordinator from prepared pieces,
    /// constructing the backend from the config's engine through the
    /// registry. For `pjrt` the artifact directory must contain a
    /// tile/division set matching `cfg.tile_size` and `cfg.batch`
    /// (`make artifacts`).
    pub fn new(
        cfg: &RunConfig,
        lut: Lut,
        mapped: &MappedArray,
        vref: &[f64],
        params: DeviceParams,
    ) -> Result<Coordinator> {
        let dispatch =
            registry::create_bank_dispatch(cfg.engine, &BackendOptions::from_config(cfg))?;
        let features = (0..lut.encoders.len()).collect();
        let rows_physical = lut.n_rows();
        Self::with_banks(
            dispatch,
            cfg.batch,
            vec![BankSpec {
                lut,
                features,
                mapped,
                vref,
                rows_physical,
            }],
            params,
        )
    }

    /// Build a single-bank coordinator over an already-constructed
    /// backend (sequential dispatch — with one bank the two modes are
    /// identical).
    pub fn with_backend(
        backend: Box<dyn MatchBackend>,
        batch: usize,
        lut: Lut,
        mapped: &MappedArray,
        vref: &[f64],
        params: DeviceParams,
    ) -> Result<Coordinator> {
        let features = (0..lut.encoders.len()).collect();
        let rows_physical = lut.n_rows();
        Self::with_banks(
            BankDispatch::Sequential(backend),
            batch,
            vec![BankSpec {
                lut,
                features,
                mapped,
                vref,
                rows_physical,
            }],
            params,
        )
    }

    /// Shared head of both construction paths: build + warm every
    /// bank's runtime, validate the class space, compute the modeled
    /// latency roll-up. The backend's per-plan caches are invalidated
    /// first so an instance reused across sessions (plan rebuilds after
    /// fault injection) never aliases stale state. `backend` is `None`
    /// for remote dispatch — the plans are still built (class-space
    /// validation, latency model, encoders) but there is nothing local
    /// to warm.
    fn build_runtimes(
        backend: Option<&dyn MatchBackend>,
        batch: usize,
        banks: Vec<BankSpec<'_>>,
        params: &DeviceParams,
    ) -> Result<(Vec<BankRuntime>, usize, f64)> {
        anyhow::ensure!(!banks.is_empty(), "a program needs at least one bank");
        if let Some(b) = backend {
            b.invalidate();
        }
        let mut runtimes = Vec::with_capacity(banks.len());
        for (b, spec) in banks.into_iter().enumerate() {
            let plan = ServingPlan::build_bank(spec.mapped, spec.vref, params, b);
            if let Some(backend) = backend {
                backend.warm(&plan, batch)?;
            }
            runtimes.push(BankRuntime {
                lut: spec.lut,
                features: spec.features,
                padded_width: spec.mapped.padded_width,
                plan: Arc::new(plan),
                scratch: Mutex::new(BatchScratch::default()),
            });
        }
        let n_classes = runtimes[0].plan.n_classes;
        // Fail fast like every other construction path: a mismatched
        // class space would otherwise surface as an out-of-bounds vote
        // index mid-batch.
        if let Some(bad) = runtimes.iter().position(|r| r.plan.n_classes != n_classes) {
            anyhow::bail!(
                "bank {bad} has {} classes but bank 0 has {n_classes} — \
                 every bank of a program must share one class space",
                runtimes[bad].plan.n_classes
            );
        }
        let latencies: Vec<f64> = runtimes.iter().map(|r| r.plan.timing.latency).collect();
        let modeled_latency = forest_latency(&latencies, params);
        Ok((runtimes, n_classes, modeled_latency))
    }

    /// Build one program's registry payload: row accounting, runtimes,
    /// class-space validation, modeled-latency roll-up, feature floor.
    /// Shared by the constructors and [`Coordinator::load_program`].
    fn build_entry(
        backend: Option<&dyn MatchBackend>,
        batch: usize,
        banks: Vec<BankSpec<'_>>,
        params: &DeviceParams,
    ) -> Result<ProgramRuntime> {
        // Row accounting before `build_runtimes` consumes the specs:
        // logical rows the banks evaluate vs rows their artifact stores.
        let rows_total: u64 = banks.iter().map(|s| s.lut.n_rows() as u64).sum();
        let rows_physical: u64 = banks.iter().map(|s| s.rows_physical as u64).sum();
        let (runtimes, n_classes, modeled_latency) =
            Self::build_runtimes(backend, batch, banks, params)?;
        let n_features = runtimes
            .iter()
            .flat_map(|b| b.features.iter().map(|&f| f + 1))
            .max()
            .unwrap_or(0);
        Ok(ProgramRuntime {
            bank_ids: (0..runtimes.len()).collect(),
            program_banks: runtimes.len(),
            program_rows_physical: rows_physical,
            n_classes,
            n_features,
            modeled_latency,
            modeled_pipe_throughput: 0.0,
            rows_total,
            rows_physical,
            banks: runtimes,
            pipeline: None,
        })
    }

    /// Build a coordinator over one-or-many banks (batch-sequential
    /// execution: each released batch runs to completion). The program
    /// is registered as [`DEFAULT_PROGRAM`] and active.
    pub fn with_banks(
        dispatch: BankDispatch,
        batch: usize,
        banks: Vec<BankSpec<'_>>,
        params: DeviceParams,
    ) -> Result<Coordinator> {
        let entry = Self::build_entry(dispatch.backend(), batch, banks, &params)?;
        // A remote dispatch must place exactly the program's banks —
        // a placement/program mismatch fails here, not mid-batch.
        if let BankDispatch::Remote(remote) = &dispatch {
            let placed = remote.lock().unwrap().n_banks();
            anyhow::ensure!(
                placed == entry.banks.len(),
                "remote dispatch places {placed} banks but the program has {}",
                entry.banks.len()
            );
        }
        // Bank fan-out pool: one worker per bank (capped like the
        // backend pools), only when the dispatch allows concurrency and
        // there is more than one bank to overlap.
        let (pool, pool_banks) = if dispatch.is_parallel() && entry.banks.len() > 1 {
            let n = entry.banks.len().min(16);
            (Some(ThreadPool::new(n)), n)
        } else {
            (None, 0)
        };
        let mut metrics = Metrics::new();
        metrics.rows_total = entry.rows_total;
        metrics.rows_physical = entry.rows_physical;
        Ok(Coordinator {
            programs: ProgramRegistry::new(DEFAULT_MAX_PROGRAMS, DEFAULT_PROGRAM, entry),
            params,
            dispatch,
            pool,
            pool_banks,
            batcher: Batcher::new(batch, Duration::from_millis(2)),
            batch,
            metrics,
            pipe: None,
            subset: None,
            tracer: Arc::new(OnceLock::new()),
            rejects: Vec::new(),
        })
    }

    /// Build a **streaming pipelined** coordinator (the paper's Table
    /// VI "P" execution mode): one live stage pipeline per bank — a
    /// thread per column division connected by bounded channels of
    /// `depth` batches — with banks streaming concurrently and several
    /// batches in flight across divisions at once. `submit`/`poll`
    /// behave exactly like the sequential coordinator's, except that
    /// `poll(false)` returns whatever batches *finished* since the last
    /// call rather than running each batch to completion; `poll(true)`
    /// drains the pipeline. Classes, modeled energy, and row activity
    /// are bit-identical to [`Coordinator::with_banks`] by
    /// construction (same kernels, same readout, same vote).
    ///
    /// The backend must be `Send + Sync` (stages run on their own
    /// threads) — [`crate::api::registry::create_pipeline_backend`]
    /// enforces this for registry engines.
    pub fn with_banks_pipelined(
        backend: Arc<dyn MatchBackend + Send + Sync>,
        batch: usize,
        banks: Vec<BankSpec<'_>>,
        params: DeviceParams,
        depth: usize,
    ) -> Result<Coordinator> {
        let mut entry = Self::build_entry(Some(backend.as_ref()), batch, banks, &params)?;
        // The tracer slot is created *before* the stage threads spawn
        // and shared with them, so a tracer attached after construction
        // (the socket server attaches on its scheduler thread) reaches
        // the per-division stage spans.
        let tracer: Arc<OnceLock<Tracer>> = Arc::new(OnceLock::new());
        Self::attach_pipeline(&mut entry, &backend, depth, &tracer);
        // The pool fans the per-bank query encoding out; the match work
        // itself is already parallel across banks (each bank's stage
        // threads run concurrently).
        let (pool, pool_banks) = if entry.banks.len() > 1 {
            let n = entry.banks.len().min(16);
            (Some(ThreadPool::new(n)), n)
        } else {
            (None, 0)
        };
        let mut metrics = Metrics::new();
        metrics.rows_total = entry.rows_total;
        metrics.rows_physical = entry.rows_physical;
        metrics.modeled_pipe_throughput = entry.modeled_pipe_throughput;
        Ok(Coordinator {
            programs: ProgramRegistry::new(DEFAULT_MAX_PROGRAMS, DEFAULT_PROGRAM, entry),
            params,
            dispatch: BankDispatch::Parallel(Arc::clone(&backend)),
            pool,
            pool_banks,
            batcher: Batcher::new(batch, Duration::from_millis(2)),
            batch,
            metrics,
            pipe: Some(PipeConfig { backend, depth }),
            subset: None,
            tracer,
            rejects: Vec::new(),
        })
    }

    /// Give one program its own live stage pipelines (a thread per
    /// column division per bank) and its modeled pipelined throughput
    /// (f_max / II — the slowest bank bounds a forest, exactly like
    /// modeled latency).
    fn attach_pipeline(
        entry: &mut ProgramRuntime,
        backend: &Arc<dyn MatchBackend + Send + Sync>,
        depth: usize,
        tracer: &Arc<OnceLock<Tracer>>,
    ) {
        let plans: Vec<Arc<ServingPlan>> =
            entry.banks.iter().map(|r| Arc::clone(&r.plan)).collect();
        let stream =
            StreamingPipeline::with_tracer(plans, Arc::clone(backend), depth, Arc::clone(tracer));
        entry.modeled_pipe_throughput = entry
            .banks
            .iter()
            .map(|r| r.plan.pipe_throughput())
            .fold(f64::INFINITY, f64::min);
        entry.pipeline = Some(PipelineState {
            stream,
            pending: HashMap::new(),
            next_seq: 0,
            busy_since: None,
        });
    }

    /// The primary (bank 0) serving plan of the **active** program —
    /// the whole plan set for single-tree programs; see
    /// [`Coordinator::bank_plans`] for all of them.
    pub fn plan(&self) -> &ServingPlan {
        &self.programs.active_slot().runtime.banks[0].plan
    }

    /// Every bank's serving plan of the active program, in bank order.
    pub fn bank_plans(&self) -> impl Iterator<Item = &ServingPlan> {
        self.programs.active_slot().runtime.banks.iter().map(|b| &*b.plan)
    }

    /// Whether this coordinator executes through the streaming stage
    /// pipeline (Table VI "P" mode) rather than batch-at-a-time.
    pub fn pipelined(&self) -> bool {
        self.pipe.is_some()
    }

    /// Batches currently inside the stage pipelines, summed over every
    /// resident program (fed, not yet fully collected); always 0 for
    /// batch-sequential coordinators and after a draining `poll(true)`.
    pub fn in_flight(&self) -> usize {
        self.programs
            .slots()
            .iter()
            .map(|s| s.runtime.pipeline.as_ref().map_or(0, |p| p.pending.len()))
            .sum()
    }

    /// Number of CAM banks the active program serves locally.
    pub fn n_banks(&self) -> usize {
        self.programs.active_slot().runtime.banks.len()
    }

    /// Global bank id of each locally served bank of the active
    /// program, ascending. Identity (`0..n_banks`) unless
    /// [`Coordinator::set_bank_ids`] relabeled the banks (cluster
    /// workers serving a placement subset).
    pub fn bank_ids(&self) -> &[usize] {
        &self.programs.active_slot().runtime.bank_ids
    }

    /// Relabel the locally served banks with their **global** ids (a
    /// cluster worker builds its coordinator from a subset of the
    /// program's bank specs, in ascending global order, then records
    /// which global banks those are). Ids must be strictly ascending —
    /// the router sums per-bank energies in global bank order, and an
    /// out-of-order subset would silently reorder that f64 sum.
    pub fn set_bank_ids(&mut self, ids: Vec<usize>) -> Result<()> {
        let n = self.programs.active_slot().runtime.banks.len();
        anyhow::ensure!(ids.len() == n, "{} bank ids for {n} banks", ids.len());
        anyhow::ensure!(
            ids.windows(2).all(|w| w[0] < w[1]),
            "bank ids must be strictly ascending, got {ids:?}"
        );
        // Remember the subset: every later-loaded program on this
        // worker serves the same global banks.
        self.subset = Some(ids.clone());
        self.programs.active_slot_mut().runtime.bank_ids = ids;
        Ok(())
    }

    /// The global bank subset this process serves (`None` = the whole
    /// program). Set by [`Coordinator::set_bank_ids`]; the admin plane
    /// uses it to slice later-loaded artifacts to the same placement.
    pub fn bank_subset(&self) -> Option<&[usize]> {
        self.subset.as_deref()
    }

    /// Attach a tracer (idempotent — the first attach wins). The shared
    /// slot makes the attachment visible to the pipeline stage threads.
    pub fn attach_tracer(&self, tracer: Tracer) {
        let _ = self.tracer.set(tracer);
    }

    /// The attached tracer, if any.
    pub fn tracer(&self) -> Option<&Tracer> {
        self.tracer.get()
    }

    /// Program identity `(artifact format, bank count, physical rows)`
    /// — the triple a serving process advertises over `Frame::Health`
    /// so a router can detect a worker holding the wrong (or stale)
    /// program.
    pub fn identity(&self) -> (&'static str, usize, u64) {
        let entry = &self.programs.active_slot().runtime;
        (MAPPED_FORMAT, entry.program_banks, entry.program_rows_physical)
    }

    /// Overwrite the advertised identity with whole-program figures (a
    /// cluster worker serves a bank *subset* but must advertise the
    /// program it was built from, or every subset would look like a
    /// different program to the router).
    pub fn set_program_identity(&mut self, banks: usize, rows_physical: u64) {
        let entry = &mut self.programs.active_slot_mut().runtime;
        entry.program_banks = banks;
        entry.program_rows_physical = rows_physical;
    }

    /// First sampled trace id in a batch: batch-level spans (dispatch,
    /// bank match, remote round-trip, vote) are recorded once against a
    /// representative traced request rather than once per lane. 0 =
    /// nothing in this batch is traced.
    fn rep_trace(batch: &[InferenceRequest]) -> u64 {
        batch.iter().map(|r| r.trace).find(|&t| t != 0).unwrap_or(0)
    }

    /// The tracer, but only when this batch has something to record —
    /// keeps fully-untraced batches at a single branch per span site.
    fn batch_tracer(&self, rep: u64) -> Option<&Tracer> {
        if rep == 0 {
            None
        } else {
            self.tracer.get()
        }
    }

    /// Per-worker status when this coordinator dispatches banks
    /// remotely (the cluster router); `None` under local dispatch.
    /// With `scrape`, each live worker's own metrics snapshot is pulled
    /// over the wire too.
    pub fn remote_status(&self, scrape: bool) -> Option<Vec<RemoteWorkerStatus>> {
        match &self.dispatch {
            BankDispatch::Remote(remote) => Some(remote.lock().unwrap().worker_status(scrape)),
            _ => None,
        }
    }

    /// Modeled per-decision latency of the active program (slowest bank
    /// + vote stage).
    pub fn modeled_latency(&self) -> f64 {
        self.programs.active_slot().runtime.modeled_latency
    }

    /// Registry name of the backend driving this coordinator.
    pub fn backend_name(&self) -> &'static str {
        self.dispatch.name()
    }

    /// Whether banks are dispatched concurrently.
    pub fn bank_parallel(&self) -> bool {
        self.pool.is_some()
    }

    /// Minimum feature-vector length a request for the **active**
    /// program must carry: the largest original-feature index any bank
    /// projects, plus one. Per-program arity is enforced exactly at
    /// submit; the socket server pre-screens frames against
    /// [`Coordinator::min_features`] (the floor across tenants) before
    /// admission.
    pub fn n_features(&self) -> usize {
        self.programs.active_slot().runtime.n_features
    }

    /// The smallest feature floor across every resident program — the
    /// most permissive admission screen that still refuses vectors no
    /// tenant could serve.
    pub fn min_features(&self) -> usize {
        self.programs
            .slots()
            .iter()
            .map(|s| s.runtime.n_features)
            .min()
            .unwrap_or(0)
    }

    /// Requests waiting in the batcher (submitted, not yet dispatched).
    pub fn pending(&self) -> usize {
        self.batcher.pending()
    }

    /// Retune the batcher's partial-batch deadline (default 2 ms). The
    /// socket server exposes this so deployments can trade tail latency
    /// for cross-connection coalescing.
    pub fn set_batch_max_wait(&mut self, max_wait: Duration) {
        self.batcher.set_max_wait(max_wait);
    }

    /// Load (or reload) a program under `id`: build + warm its bank
    /// runtimes exactly like the constructor did for the boot program,
    /// attach per-program stage pipelines in pipelined mode, and insert
    /// it into the registry (LRU-evicting an idle tenant when full —
    /// never the active program or one with requests in flight).
    /// Returns the stamped version. Serving of resident programs is
    /// untouched: loading is activation-free.
    ///
    /// `program_banks` / `program_rows_physical` are the **whole**
    /// program's identity figures (a cluster worker passes the full
    /// program's even though `banks` is its placement subset).
    pub fn load_program(
        &mut self,
        id: &str,
        banks: Vec<BankSpec<'_>>,
        program_banks: usize,
        program_rows_physical: u64,
    ) -> Result<u64> {
        let mut entry = Self::build_entry(self.dispatch.backend(), self.batch, banks, &self.params)?;
        if let Some(subset) = &self.subset {
            anyhow::ensure!(
                subset.len() == entry.banks.len(),
                "this worker serves {} global banks but program {id:?} \
                 was loaded with {} bank specs",
                subset.len(),
                entry.banks.len()
            );
            entry.bank_ids = subset.clone();
        }
        entry.program_banks = program_banks;
        entry.program_rows_physical = program_rows_physical;
        // A remote dispatch (cluster router) fans every program out
        // over the same placement — bank counts must agree.
        if let BankDispatch::Remote(remote) = &self.dispatch {
            let placed = remote.lock().unwrap().n_banks();
            anyhow::ensure!(
                placed == entry.banks.len(),
                "remote dispatch places {placed} banks but program {id:?} has {}",
                entry.banks.len()
            );
        }
        if let Some(pipe) = &self.pipe {
            Self::attach_pipeline(&mut entry, &pipe.backend, pipe.depth, &self.tracer);
        }
        // Grow the bank fan-out pool if this tenant is wider than any
        // resident program was.
        let n = entry.banks.len().min(16);
        if self.dispatch.is_parallel() && entry.banks.len() > 1 && n > self.pool_banks {
            self.pool = Some(ThreadPool::new(n));
            self.pool_banks = n;
        }
        self.programs.insert(id, entry)
    }

    /// Make `id` the target of all *future* unpinned admissions —
    /// atomic at the admission point. Nothing drains: batches admitted
    /// before the flip finish on the version stamped at their
    /// admission. Returns the activated version.
    pub fn activate_program(&mut self, id: &str) -> Result<u64> {
        let version = self.programs.activate(id)?;
        // Aggregate metrics carry the active program's row figures.
        let entry = &self.programs.active_slot().runtime;
        let (rows_total, rows_physical, pipe_tp) =
            (entry.rows_total, entry.rows_physical, entry.modeled_pipe_throughput);
        self.metrics.rows_total = rows_total;
        self.metrics.rows_physical = rows_physical;
        self.metrics.modeled_pipe_throughput = pipe_tp;
        Ok(version)
    }

    /// The id unpinned traffic currently routes to.
    pub fn active_program(&self) -> &str {
        self.programs.active_id()
    }

    /// Every resident program (registry order).
    pub fn program_list(&self) -> Vec<ProgramStatus> {
        let active = self.programs.active_id().to_string();
        self.programs
            .slots()
            .iter()
            .map(|s| ProgramStatus {
                id: s.id.clone(),
                version: s.version,
                active: s.id == active,
                banks: s.runtime.program_banks,
                rows_physical: s.runtime.program_rows_physical,
                in_flight: s.in_flight(),
            })
            .collect()
    }

    /// Resident-program bound (LRU eviction horizon).
    pub fn max_programs(&self) -> usize {
        self.programs.cap()
    }

    /// Retune the resident-program bound (`serve --max-programs`).
    pub fn set_max_programs(&mut self, cap: usize) {
        self.programs.set_cap(cap);
    }

    /// Enqueue one request. Admission is where the lifecycle bites:
    /// the request's pin (or the active id) resolves to a registry slot
    /// *now*, the batch key stamps `(id, version)`, and the slot's
    /// in-flight count pins it against reload/eviction until answered.
    /// Refusals (unknown pin, short feature vector) become typed error
    /// responses on the next poll — never a panic mid-batch.
    ///
    /// The queueing delay is *not* recorded here — at submission the
    /// request has waited ~0; [`Coordinator::poll`] records the real
    /// arrival → batch-dispatch delay when the batcher releases the
    /// request.
    pub fn submit(&mut self, req: InferenceRequest) {
        self.metrics.record_request();
        let Some(idx) = self.programs.resolve(req.program.as_deref()) else {
            let pin = req.program.clone().unwrap_or_default();
            self.rejects.push(InferenceResponse {
                id: req.id,
                class: None,
                modeled_latency: 0.0,
                error: Some(format!(
                    "unknown program {pin:?} (resident: {:?})",
                    self.programs.ids()
                )),
                trace: req.trace,
                program: pin,
                version: 0,
            });
            return;
        };
        let (need, id, version) = {
            let slot = self.programs.slot(idx);
            (slot.runtime.n_features, slot.id.clone(), slot.version)
        };
        if req.features.len() < need {
            self.rejects.push(InferenceResponse {
                id: req.id,
                class: None,
                modeled_latency: 0.0,
                error: Some(format!(
                    "request {} carries {} features but program {id:?} needs at least {need}",
                    req.id,
                    req.features.len()
                )),
                trace: req.trace,
                program: id,
                version,
            });
            return;
        }
        self.programs.begin(idx, 1);
        self.batcher.push(BatchKey::new(&id, version), req);
    }

    /// Run all due batches; returns responses (request order within batch
    /// preserved). `force_flush` drains partial batches (end of stream).
    ///
    /// Pipelined coordinators *feed* due batches and return whatever
    /// batches finished since the last poll — responses for a given
    /// submit may arrive on a later poll, in pipeline-completion order.
    /// `poll(true)` additionally blocks until every in-flight batch has
    /// drained, so a forced flush answers everything submitted in both
    /// modes.
    pub fn poll(&mut self, force_flush: bool) -> Result<Vec<InferenceResponse>> {
        // Admission refusals ride out with (ahead of) served answers.
        let mut responses = std::mem::take(&mut self.rejects);
        let batches = self.batcher.take_due(Instant::now(), force_flush);
        if self.pipe.is_some() {
            responses.extend(self.poll_pipelined(batches, force_flush)?);
            return Ok(responses);
        }
        for (key, batch) in batches {
            responses.extend(self.run_batch(&key, batch)?);
        }
        Ok(responses)
    }

    /// Slot index a stamped batch runs on. In-flight accounting makes a
    /// miss unreachable (a stamped program cannot be reloaded or
    /// evicted while requests are in flight) — still answered typed,
    /// never unwrapped.
    fn program_index(&self, key: &BatchKey) -> Option<usize> {
        self.programs
            .index_of(&key.program)
            .filter(|&i| self.programs.slot(i).version == key.version)
    }

    /// Typed error responses for a whole batch, stamped with its
    /// admission key.
    fn batch_errors(
        batch: &[InferenceRequest],
        message: &str,
        modeled_latency: f64,
        key: &BatchKey,
    ) -> Vec<InferenceResponse> {
        batch
            .iter()
            .map(|r| InferenceResponse {
                id: r.id,
                class: None,
                modeled_latency,
                error: Some(message.to_string()),
                trace: r.trace,
                program: key.program.clone(),
                version: key.version,
            })
            .collect()
    }

    /// Evaluate one bank for one encoded batch (shared by both dispatch
    /// paths).
    fn run_bank(
        bank: &BankRuntime,
        params: &DeviceParams,
        backend: &dyn MatchBackend,
        queries: &[Vec<bool>],
        real: usize,
    ) -> Result<BatchOutcome> {
        let sched = Scheduler::new(&bank.plan, params);
        let mut scratch = bank.scratch.lock().unwrap();
        sched.run_batch_with(backend, queries, real, &mut scratch)
    }

    /// Encode + pad one batch of raw feature rows to `width` lanes for
    /// one bank: the bank sees its own feature projection through its
    /// own encoders; one reusable projection buffer serves every lane.
    fn encode_bank_rows(bank: &BankRuntime, rows: &[&[f64]], width: usize) -> Vec<Vec<bool>> {
        let mut proj: Vec<f64> = Vec::new();
        let mut qs: Vec<Vec<bool>> = rows
            .iter()
            .map(|x| {
                proj.clear();
                proj.extend(bank.features.iter().map(|&f| x[f]));
                bank.plan.encode(&bank.lut, bank.padded_width, &proj)
            })
            .collect();
        while qs.len() < width {
            qs.push(vec![false; bank.padded_width]);
        }
        qs
    }

    /// Encode + pad one admitted batch to the artifact width, once per
    /// bank of the batch's program (`idx`). Fanned out over the bank
    /// pool when one exists (the per-bank encodes are independent).
    fn encode_banks(&self, idx: usize, batch: &[InferenceRequest], width: usize) -> Vec<Vec<Vec<bool>>> {
        let rows: Vec<&[f64]> = batch.iter().map(|r| r.features.as_slice()).collect();
        let banks = &self.programs.slot(idx).runtime.banks;
        match &self.pool {
            Some(pool) if banks.len() > 1 => {
                let rows = &rows;
                pool.scoped_map(banks.len(), |b| Self::encode_bank_rows(&banks[b], rows, width))
            }
            _ => banks
                .iter()
                .map(|b| Self::encode_bank_rows(b, &rows, width))
                .collect(),
        }
    }

    fn run_batch(
        &mut self,
        key: &BatchKey,
        batch: Vec<InferenceRequest>,
    ) -> Result<Vec<InferenceResponse>> {
        let width = self.batcher.batch_width();
        let real = batch.len();
        // The queue delay is measured here, at batch dispatch: this is
        // the full batcher wait (arrival → drain), which a deadline-
        // released partial batch reports as >= max_wait.
        for r in &batch {
            self.metrics.record_queue_delay(r.arrived.elapsed());
        }
        let rep = Self::rep_trace(&batch);
        let tracer = self.batch_tracer(rep).cloned();
        if let Some(tr) = tracer.as_ref() {
            // One queue span per traced request — its personal batcher
            // wait, not the batch representative's.
            let now = tr.now_ns();
            for r in batch.iter().filter(|r| r.trace != 0) {
                let start = tr.ns_at(r.arrived);
                tr.record(r.trace, SpanKind::Queue, None, None, start, now.saturating_sub(start));
            }
        }

        // The admission stamp resolves to its slot — unreachable-miss
        // guarded with a typed batch error, see `program_index`.
        let Some(idx) = self.program_index(key) else {
            self.programs.finish(&key.program, real as u64);
            let message = format!(
                "program {:?} version {} vanished mid-flight (resident: {:?})",
                key.program,
                key.version,
                self.programs.ids()
            );
            return Ok(Self::batch_errors(&batch, &message, 0.0, key));
        };

        // Remote dispatch (cluster router): the raw rows go over the
        // wire — each worker encodes them against its own copy of the
        // artifact — and a failed dispatch (bank unserveable after
        // failover) answers every request of the batch with a typed
        // error, exactly like the pipelined poisoned-batch path. It
        // must never `?` out of here: that would kill the serving loop
        // over one lost worker. Batches are stamped with the program's
        // identity so a worker holding different bits refuses rather
        // than silently answering.
        if let BankDispatch::Remote(remote) = &self.dispatch {
            let (n_banks, modeled_latency, stamp) = {
                let entry = &self.programs.slot(idx).runtime;
                (
                    entry.banks.len(),
                    entry.modeled_latency,
                    ProgramStamp {
                        id: key.program.clone(),
                        banks: entry.program_banks,
                        rows_physical: entry.program_rows_physical,
                    },
                )
            };
            let rows: Vec<Vec<f64>> = batch.iter().map(|r| r.features.clone()).collect();
            let t0 = Instant::now();
            let result = remote
                .lock()
                .unwrap()
                .run_banks(&rows, rep, &stamp)
                .and_then(|o| Self::check_remote_outcomes(o, n_banks, real));
            let wall = t0.elapsed();
            if let Some(tr) = tracer.as_ref() {
                // One remote span for the whole fan-out: send the bank
                // batches, wait for every worker's outcomes.
                tr.record(
                    rep,
                    SpanKind::Remote,
                    None,
                    None,
                    tr.ns_at(t0),
                    wall.as_nanos() as u64,
                );
            }
            return Ok(match result {
                Ok(outcomes) => self.finish_batch(idx, &batch, &outcomes, wall),
                Err(e) => {
                    self.metrics.stage_errors += 1;
                    self.programs.finish(&key.program, real as u64);
                    Self::batch_errors(&batch, &format!("{e:#}"), modeled_latency, key)
                }
            });
        }

        // The dispatch span covers forming the batch for the hardware:
        // per-bank encode + pad (the launch itself is the bank-match
        // spans that follow).
        let enc0 = tracer.as_ref().map(|t| t.now_ns());
        let bank_queries = self.encode_banks(idx, &batch, width);
        if let (Some(tr), Some(s)) = (tracer.as_ref(), enc0) {
            tr.record(rep, SpanKind::Dispatch, None, None, s, tr.now_ns().saturating_sub(s));
        }

        let t0 = Instant::now();
        let entry = &self.programs.slot(idx).runtime;
        let outcomes: Vec<BatchOutcome> = match (&self.pool, &self.dispatch) {
            (Some(pool), BankDispatch::Parallel(backend)) => {
                // Bank fan-out: banks are independent CAM arrays, the
                // backend is shared (&self), scratch is per-bank.
                let banks = &entry.banks;
                let params = &self.params;
                let tr = tracer.as_ref();
                let backend: &(dyn MatchBackend + Send + Sync) = backend.as_ref();
                pool.scoped_map(banks.len(), |b| {
                    let s = tr.map(|t| t.now_ns());
                    let out = Self::run_bank(&banks[b], params, backend, &bank_queries[b], real);
                    if let (Some(t), Some(s)) = (tr, s) {
                        t.record(
                            rep,
                            SpanKind::BankMatch,
                            Some(b),
                            None,
                            s,
                            t.now_ns().saturating_sub(s),
                        );
                    }
                    out
                })
                .into_iter()
                .collect::<Result<Vec<_>>>()?
            }
            _ => {
                let backend = self.dispatch.backend().expect("local dispatch");
                let tr = tracer.as_ref();
                entry
                    .banks
                    .iter()
                    .enumerate()
                    .map(|(b, bank)| {
                        let s = tr.map(|t| t.now_ns());
                        let out =
                            Self::run_bank(bank, &self.params, backend, &bank_queries[b], real);
                        if let (Some(t), Some(s)) = (tr, s) {
                            t.record(
                                rep,
                                SpanKind::BankMatch,
                                Some(b),
                                None,
                                s,
                                t.now_ns().saturating_sub(s),
                            );
                        }
                        out
                    })
                    .collect::<Result<Vec<_>>>()?
            }
        };
        let wall = t0.elapsed();
        Ok(self.finish_batch(idx, &batch, &outcomes, wall))
    }

    /// Validate remote outcomes and convert them to the scheduler's
    /// batch-outcome shape: exactly one outcome per bank, ascending
    /// global ids 0..n (the router serves the whole program, so global
    /// and local ids coincide), and a class per real lane. Anything
    /// else is a protocol violation answered as a typed batch error.
    fn check_remote_outcomes(
        outcomes: Vec<RemoteBankOutcome>,
        n_banks: usize,
        real: usize,
    ) -> Result<Vec<BatchOutcome>> {
        anyhow::ensure!(
            outcomes.len() == n_banks,
            "remote dispatch answered {} banks, program has {n_banks}",
            outcomes.len()
        );
        outcomes
            .into_iter()
            .enumerate()
            .map(|(i, o)| {
                anyhow::ensure!(
                    o.bank == i,
                    "remote outcomes out of order: bank {} at position {i}",
                    o.bank
                );
                anyhow::ensure!(
                    o.classes.len() >= real,
                    "bank {i} answered {} lanes for a {real}-row batch",
                    o.classes.len()
                );
                Ok(BatchOutcome {
                    bank: o.bank,
                    classes: o.classes,
                    modeled_energy: o.modeled_energy,
                    active_row_evals: o.active_row_evals,
                    divisions_evaluated: o.divisions_evaluated,
                    no_match: o.no_match,
                    multi_match: o.multi_match,
                })
            })
            .collect()
    }

    /// Shared tail of every batch-sequential execution path (local or
    /// remote): vote, roll up the hardware cost, materialize responses.
    /// Keeping this literally shared is what makes the cluster router
    /// bit-identical to single-process serving — same vote, same f64
    /// energy sum in the same bank order.
    fn finish_batch(
        &mut self,
        idx: usize,
        batch: &[InferenceRequest],
        outcomes: &[BatchOutcome],
        wall: Duration,
    ) -> Vec<InferenceResponse> {
        let real = batch.len();
        let (n_classes, modeled_latency, id, version) = {
            let slot = self.programs.slot(idx);
            (
                slot.runtime.n_classes,
                slot.runtime.modeled_latency,
                slot.id.clone(),
                slot.version,
            )
        };
        let rep = Self::rep_trace(batch);
        let tracer = self.batch_tracer(rep).cloned();
        let vote0 = tracer.as_ref().map(|t| t.now_ns());
        // Combine survivors with the normative forest rule
        // (`cart::vote_survivors`: silent banks cast no vote, ties →
        // lowest class id, no votes at all → no-match).
        let mut classes = Vec::with_capacity(real);
        let mut no_match = 0usize;
        let mut votes = Vec::new();
        for lane in 0..real {
            let c = vote_survivors(
                outcomes.iter().map(|out| out.classes[lane]),
                n_classes,
                &mut votes,
            );
            if c.is_none() {
                no_match += 1;
            }
            classes.push(c);
        }
        if let (Some(tr), Some(s)) = (tracer.as_ref(), vote0) {
            tr.record(rep, SpanKind::Vote, None, None, s, tr.now_ns().saturating_sub(s));
        }

        // Roll up the hardware cost: energy and row activity sum over
        // banks (each array burns its own joules); multi-match events
        // are per-bank hardware events and also sum.
        let modeled_energy: f64 = outcomes.iter().map(|o| o.modeled_energy).sum();
        let active_rows: u64 = outcomes.iter().map(|o| o.active_row_evals).sum();
        let multi_match: usize = outcomes.iter().map(|o| o.multi_match).sum();
        for out in outcomes {
            self.metrics.record_bank_energy(out.bank, out.modeled_energy);
        }
        self.metrics.record_batch(
            real,
            modeled_energy,
            active_rows,
            no_match,
            multi_match,
            wall,
        );
        self.metrics.wall_total += wall.as_secs_f64();
        // End-to-end latency sample per request — arrival → response
        // materialization (queue delay + batch service) — feeding the
        // p50/p95/p99 roll-ups in `summary_line` and the net metrics
        // frame.
        for r in batch {
            self.metrics.record_latency(r.arrived.elapsed());
        }
        // Per-program attribution + in-flight retirement: the batch is
        // answered, its slot is unpinned.
        self.metrics.record_program(&id, real as u64, modeled_energy);
        self.programs.finish(&id, real as u64);

        batch
            .iter()
            .zip(&classes)
            .map(|(req, &class)| InferenceResponse {
                id: req.id,
                class,
                modeled_latency,
                error: None,
                trace: req.trace,
                program: id.clone(),
                version,
            })
            .collect()
    }

    /// Evaluate one externally-batched set of raw rows on a subset of
    /// this coordinator's banks, named by **global** bank id — the
    /// worker-side entry of the cluster's remote bank dispatch. The
    /// rows arrive exactly as the router batched them and bypass the
    /// local batcher, and the queries are encoded at `rows.len()` lanes
    /// (padding lanes are provably free — they carry no cost and no
    /// vote — so no width round-up is needed); the per-bank outcomes
    /// are therefore bit-identical to the single-process walk of the
    /// same batch. No vote happens here: the router joins. Metrics are
    /// recorded at bank granularity (`no_match`/`multi_match` sum over
    /// the *served banks*, not over joined votes). `trace` is the
    /// router's representative trace id for the batch (0 = untraced) —
    /// the worker's bank-match spans are stamped with it so a scrape of
    /// the worker correlates with the router's remote span.
    /// `program` names the tenant the batch belongs to (empty = the
    /// worker's active program, the pre-lifecycle wire behavior);
    /// `pbanks`/`prows` are the router's identity stamp for that
    /// program (0/0 = unstamped legacy batch, accepted unchecked). A
    /// worker holding different bits under that id — or not holding the
    /// id at all — refuses with a typed error instead of answering from
    /// the wrong program.
    pub fn run_bank_batch(
        &mut self,
        program: &str,
        pbanks: usize,
        prows: u64,
        banks: &[usize],
        rows: &[Vec<f64>],
        trace: u64,
    ) -> Result<Vec<RemoteBankOutcome>> {
        anyhow::ensure!(!banks.is_empty(), "bank batch names no banks");
        anyhow::ensure!(!rows.is_empty(), "bank batch carries no rows");
        let idx = if program.is_empty() {
            self.programs.resolve(None).expect("active program")
        } else {
            self.programs.index_of(program).with_context(|| {
                format!(
                    "program {program:?} is not loaded on this worker (resident: {:?})",
                    self.programs.ids()
                )
            })?
        };
        let resolved_id = self.programs.slot(idx).id.clone();
        let entry = &self.programs.slot(idx).runtime;
        if pbanks != 0 || prows != 0 {
            anyhow::ensure!(
                pbanks == entry.program_banks && prows == entry.program_rows_physical,
                "program {resolved_id:?} identity mismatch: batch stamped \
                 {pbanks} banks / {prows} physical rows, this worker holds \
                 {} banks / {} rows",
                entry.program_banks,
                entry.program_rows_physical
            );
        }
        let locals: Vec<usize> = banks
            .iter()
            .map(|g| {
                entry
                    .bank_ids
                    .iter()
                    .position(|id| id == g)
                    .with_context(|| {
                        format!("bank {g} is not served here (serving {:?})", entry.bank_ids)
                    })
            })
            .collect::<Result<_>>()?;
        let need = entry.n_features;
        for (i, r) in rows.iter().enumerate() {
            anyhow::ensure!(
                r.len() >= need,
                "bank-batch row {i} carries {} features, banks here need {need}",
                r.len()
            );
        }
        let real = rows.len();
        let row_refs: Vec<&[f64]> = rows.iter().map(|r| r.as_slice()).collect();
        self.metrics.requests += real as u64;
        let tracer = self.batch_tracer(trace).cloned();
        let t0 = Instant::now();
        let outcomes: Vec<BatchOutcome> = match (&self.pool, &self.dispatch) {
            (Some(pool), BankDispatch::Parallel(backend)) if locals.len() > 1 => {
                let banks_rt = &entry.banks;
                let params = &self.params;
                let backend: &(dyn MatchBackend + Send + Sync) = backend.as_ref();
                let locals = &locals;
                let row_refs = &row_refs;
                let tr = tracer.as_ref();
                let bank_ids = &entry.bank_ids;
                pool.scoped_map(locals.len(), |k| {
                    let b = locals[k];
                    let s = tr.map(|t| t.now_ns());
                    let queries = Self::encode_bank_rows(&banks_rt[b], row_refs, real);
                    let out = Self::run_bank(&banks_rt[b], params, backend, &queries, real);
                    if let (Some(t), Some(s)) = (tr, s) {
                        // Stamped with the *global* bank id — that is
                        // the id the router's spans speak.
                        t.record(
                            trace,
                            SpanKind::BankMatch,
                            Some(bank_ids[b]),
                            None,
                            s,
                            t.now_ns().saturating_sub(s),
                        );
                    }
                    out
                })
                .into_iter()
                .collect::<Result<Vec<_>>>()?
            }
            _ => {
                let backend = self
                    .dispatch
                    .backend()
                    .context("a remote-dispatch coordinator cannot serve bank batches")?;
                let tr = tracer.as_ref();
                locals
                    .iter()
                    .map(|&b| {
                        let s = tr.map(|t| t.now_ns());
                        let queries = Self::encode_bank_rows(&entry.banks[b], &row_refs, real);
                        let out =
                            Self::run_bank(&entry.banks[b], &self.params, backend, &queries, real);
                        if let (Some(t), Some(s)) = (tr, s) {
                            t.record(
                                trace,
                                SpanKind::BankMatch,
                                Some(entry.bank_ids[b]),
                                None,
                                s,
                                t.now_ns().saturating_sub(s),
                            );
                        }
                        out
                    })
                    .collect::<Result<Vec<_>>>()?
            }
        };
        let wall = t0.elapsed();
        let bank_ids = entry.bank_ids.clone();

        // Bank-granularity roll-ups (the vote-level figures live on the
        // router, which sees every bank).
        let modeled_energy: f64 = outcomes.iter().map(|o| o.modeled_energy).sum();
        let active_rows: u64 = outcomes.iter().map(|o| o.active_row_evals).sum();
        let no_match: usize = outcomes.iter().map(|o| o.no_match).sum();
        let multi_match: usize = outcomes.iter().map(|o| o.multi_match).sum();
        for out in &outcomes {
            self.metrics.record_bank_energy(out.bank, out.modeled_energy);
        }
        self.metrics
            .record_batch(real, modeled_energy, active_rows, no_match, multi_match, wall);
        self.metrics.wall_total += wall.as_secs_f64();
        self.metrics
            .record_program(&resolved_id, real as u64, modeled_energy);

        // Stamp global ids on the way out (outcome.bank is the local
        // plan index here — a worker's bank 0 may be global bank 4).
        Ok(outcomes
            .into_iter()
            .map(|o| RemoteBankOutcome {
                bank: bank_ids[o.bank],
                classes: o.classes,
                modeled_energy: o.modeled_energy,
                active_row_evals: o.active_row_evals,
                divisions_evaluated: o.divisions_evaluated,
                no_match: o.no_match,
                multi_match: o.multi_match,
            })
            .collect())
    }

    // -------------------------------------------- pipelined execution

    /// Pipelined poll: feed every due batch into its program's bank
    /// pipelines, then collect whatever finished — across *every*
    /// resident program, so a pinned tenant's answers are never gated
    /// on the active tenant's traffic. With `drain`, block until all
    /// pipelines are empty (end of stream / graceful shutdown).
    fn poll_pipelined(
        &mut self,
        batches: Vec<(BatchKey, Vec<InferenceRequest>)>,
        drain: bool,
    ) -> Result<Vec<InferenceResponse>> {
        let mut responses = Vec::new();
        for (key, batch) in batches {
            self.feed_pipeline(&key, batch, &mut responses)?;
        }
        // Non-blocking sweep of everything the stages finished.
        for idx in 0..self.programs.len() {
            while let Some(outcome) = self.try_next_outcome(idx) {
                self.absorb_outcome(idx, outcome, &mut responses);
            }
        }
        if drain {
            // Stage threads are always making progress on in-flight
            // batches, so a bounded wait per outcome suffices; a
            // timeout can only mean a stage thread died.
            loop {
                let Some(idx) = (0..self.programs.len()).find(|&i| {
                    self.programs
                        .slot(i)
                        .runtime
                        .pipeline
                        .as_ref()
                        .map_or(false, |p| !p.pending.is_empty())
                }) else {
                    break;
                };
                let next = self
                    .programs
                    .slot(idx)
                    .runtime
                    .pipeline
                    .as_ref()
                    .expect("pipelined mode")
                    .stream
                    .next_timeout(PIPELINE_DRAIN_TIMEOUT)?;
                match next {
                    Some(outcome) => self.absorb_outcome(idx, outcome, &mut responses),
                    None => anyhow::bail!(
                        "pipeline drain stalled with {} batches in flight",
                        self.in_flight()
                    ),
                }
            }
        }
        self.roll_busy_spans();
        Ok(responses)
    }

    /// Encode one released batch for every bank and feed the bank
    /// pipelines. A blocking feed (bounded stage channels) is the
    /// backpressure path: the caller waits while the stages drain
    /// forward — in-flight work is bounded by channel depth × stages,
    /// never by offered load.
    fn feed_pipeline(
        &mut self,
        key: &BatchKey,
        batch: Vec<InferenceRequest>,
        responses: &mut Vec<InferenceResponse>,
    ) -> Result<()> {
        let width = self.batcher.batch_width();
        let real = batch.len();
        // Queue delay at batch dispatch, like the sequential path.
        for r in &batch {
            self.metrics.record_queue_delay(r.arrived.elapsed());
        }
        let rep = Self::rep_trace(&batch);
        let tracer = self.batch_tracer(rep).cloned();
        if let Some(tr) = tracer.as_ref() {
            let now = tr.now_ns();
            for r in batch.iter().filter(|r| r.trace != 0) {
                let start = tr.ns_at(r.arrived);
                tr.record(r.trace, SpanKind::Queue, None, None, start, now.saturating_sub(start));
            }
        }
        // The admission stamp resolves to its slot — unreachable-miss
        // guarded with a typed batch error, see `program_index`.
        let Some(idx) = self.program_index(key) else {
            self.programs.finish(&key.program, real as u64);
            let message = format!(
                "program {:?} version {} vanished mid-flight (resident: {:?})",
                key.program,
                key.version,
                self.programs.ids()
            );
            responses.extend(Self::batch_errors(&batch, &message, 0.0, key));
            return Ok(());
        };
        // The dispatch span covers encode + feed: a blocking feed means
        // the pipeline applied backpressure, and that wait is honest
        // dispatch time.
        let enc0 = tracer.as_ref().map(|t| t.now_ns());
        let bank_queries = self.encode_banks(idx, &batch, width);
        let n_banks = self.programs.slot(idx).runtime.banks.len();
        let state = self
            .programs
            .slot_mut(idx)
            .runtime
            .pipeline
            .as_mut()
            .expect("pipelined mode");
        let seq = state.next_seq;
        state.next_seq += 1;
        state.busy_since.get_or_insert_with(Instant::now);
        state.pending.insert(
            seq,
            PendingPipe {
                reqs: batch,
                outcomes: (0..n_banks).map(|_| None).collect(),
                remaining: n_banks,
                fed: Instant::now(),
            },
        );
        let state = self
            .programs
            .slot(idx)
            .runtime
            .pipeline
            .as_ref()
            .expect("pipelined mode");
        for (b, queries) in bank_queries.into_iter().enumerate() {
            state.stream.feed_traced(b, seq, queries, real, rep)?;
        }
        if let (Some(tr), Some(s)) = (tracer.as_ref(), enc0) {
            tr.record(rep, SpanKind::Dispatch, None, None, s, tr.now_ns().saturating_sub(s));
        }
        Ok(())
    }

    /// Record one bank outcome; when its batch is complete, vote, roll
    /// up the hardware cost, and materialize the responses.
    fn absorb_outcome(
        &mut self,
        idx: usize,
        outcome: PipeOutcome,
        responses: &mut Vec<InferenceResponse>,
    ) {
        let seq = outcome.seq;
        let bank = outcome.bank;
        let entry = {
            let state = self
                .programs
                .slot_mut(idx)
                .runtime
                .pipeline
                .as_mut()
                .expect("pipelined mode");
            let entry = state
                .pending
                .get_mut(&seq)
                .expect("pipeline outcome for unknown batch");
            debug_assert!(entry.outcomes[bank].is_none(), "duplicate bank outcome");
            entry.outcomes[bank] = Some(outcome);
            entry.remaining -= 1;
            if entry.remaining > 0 {
                return;
            }
            state.pending.remove(&seq).expect("entry just seen")
        };
        let (n_classes, modeled_latency, id, version) = {
            let slot = self.programs.slot(idx);
            (
                slot.runtime.n_classes,
                slot.runtime.modeled_latency,
                slot.id.clone(),
                slot.version,
            )
        };
        let residence = entry.fed.elapsed();
        let outcomes: Vec<PipeOutcome> = entry
            .outcomes
            .into_iter()
            .map(|o| o.expect("complete batch"))
            .collect();
        let real = entry.reqs.len();

        // A poisoned batch answers every one of its requests with the
        // typed stage error — and nothing else: no cost roll-up for
        // work the hardware model cannot attribute. Later batches are
        // unaffected (they flowed around the failure in the stages).
        if let Some(err) = outcomes.iter().find_map(|o| o.error.as_ref()) {
            let message = err.to_string();
            self.metrics.stage_errors += 1;
            self.programs.finish(&id, real as u64);
            responses.extend(entry.reqs.iter().map(|r| InferenceResponse {
                id: r.id,
                class: None,
                modeled_latency,
                error: Some(message.clone()),
                trace: r.trace,
                program: id.clone(),
                version,
            }));
            return;
        }

        let rep = Self::rep_trace(&entry.reqs);
        let tracer = self.batch_tracer(rep).cloned();
        let vote0 = tracer.as_ref().map(|t| t.now_ns());
        // Combine survivors with the normative forest rule — identical
        // to the sequential path (`outcomes` is in bank order).
        let mut classes = Vec::with_capacity(real);
        let mut no_match = 0usize;
        let mut votes = Vec::new();
        for lane in 0..real {
            let c = vote_survivors(
                outcomes.iter().map(|out| out.classes[lane]),
                n_classes,
                &mut votes,
            );
            if c.is_none() {
                no_match += 1;
            }
            classes.push(c);
        }
        if let (Some(tr), Some(s)) = (tracer.as_ref(), vote0) {
            tr.record(rep, SpanKind::Vote, None, None, s, tr.now_ns().saturating_sub(s));
        }

        let modeled_energy: f64 = outcomes.iter().map(|o| o.modeled_energy).sum();
        let active_rows: u64 = outcomes.iter().map(|o| o.active_row_evals).sum();
        let multi_match: usize = outcomes.iter().map(|o| o.multi_match).sum();
        for out in &outcomes {
            self.metrics.record_bank_energy(out.bank, out.modeled_energy);
        }
        // `residence` is this batch's pipeline dwell (feed → joined):
        // the honest per-batch figure in a pipelined system. Batches
        // overlap, so it feeds the per-batch stats only — wall_total is
        // accumulated from busy spans instead (see `PipelineState`).
        self.metrics.record_batch(
            real,
            modeled_energy,
            active_rows,
            no_match,
            multi_match,
            residence,
        );
        for r in &entry.reqs {
            self.metrics.record_latency(r.arrived.elapsed());
        }
        self.metrics.record_program(&id, real as u64, modeled_energy);
        self.programs.finish(&id, real as u64);
        responses.extend(entry.reqs.iter().zip(&classes).map(|(req, &class)| {
            InferenceResponse {
                id: req.id,
                class,
                modeled_latency,
                error: None,
                trace: req.trace,
                program: id.clone(),
                version,
            }
        }));
    }

    /// One finished outcome of program `idx`'s pipeline, if any (scopes
    /// the pipeline borrow so the caller can absorb with `&mut self`).
    fn try_next_outcome(&self, idx: usize) -> Option<PipeOutcome> {
        self.programs.slot(idx).runtime.pipeline.as_ref()?.stream.try_next()
    }

    /// Fold the elapsed slice of every program's current busy span into
    /// `Metrics::wall_total` (called at the end of every pipelined
    /// poll). While batches remain in flight the span marker advances
    /// to "now", so sustained load keeps `wall_throughput` current;
    /// once a pipeline drains its marker clears and idle time stops
    /// counting. (Tenants streaming simultaneously overlap in wall
    /// time; the roll-up counts each program's busy span, matching the
    /// single-tenant convention per program.)
    fn roll_busy_spans(&mut self) {
        let now = Instant::now();
        let mut add = 0.0;
        for slot in self.programs.slots_mut() {
            if let Some(state) = slot.runtime.pipeline.as_mut() {
                if let Some(t0) = state.busy_since.as_mut() {
                    add += now.duration_since(*t0).as_secs_f64();
                    if state.pending.is_empty() {
                        state.busy_since = None;
                    } else {
                        *t0 = now;
                    }
                }
            }
        }
        self.metrics.wall_total += add;
    }

    /// Convenience: synchronous classification of a whole test set in
    /// batch-width chunks (examples + benches). Works identically over
    /// both execution modes — pipelined responses simply arrive on
    /// later polls and are re-ordered by request id here. A served
    /// error (pipelined stage failure) surfaces as `Err`.
    pub fn classify_all(&mut self, inputs: &[Vec<f64>]) -> Result<Vec<Option<usize>>> {
        let mut out = Vec::with_capacity(inputs.len());
        for (i, x) in inputs.iter().enumerate() {
            self.submit(InferenceRequest::new(i as u64, x.clone()));
            for r in self.poll(false)? {
                if let Some(e) = r.error {
                    anyhow::bail!("request {} failed: {e}", r.id);
                }
                out.push((r.id, r.class));
            }
        }
        for r in self.poll(true)? {
            if let Some(e) = r.error {
                anyhow::bail!("request {} failed: {e}", r.id);
            }
            out.push((r.id, r.class));
        }
        let mut sorted = out;
        sorted.sort_by_key(|(id, _)| *id);
        Ok(sorted.into_iter().map(|(_, c)| c).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cart::{train, TrainParams};
    use crate::compiler::compile;
    use crate::config::EngineKind;
    use crate::dataset::catalog;
    use crate::util::prng::Prng;

    fn build(
        engine: EngineKind,
        dataset: &str,
        s: usize,
    ) -> (Coordinator, Vec<Vec<f64>>, Vec<usize>) {
        let mut d = catalog::by_name(dataset, 0xD72CA0).unwrap();
        d.normalize();
        let mut rng = Prng::new(11);
        let split = d.split(0.9, &mut rng);
        let (xs, ys) = d.gather(&split.train);
        let tree = train(&xs, &ys, d.n_classes, &TrainParams::default());
        let lut = compile(&tree);
        let p = DeviceParams::default();
        let m = MappedArray::from_lut(&lut, s, &p, &mut rng);
        let cfg = RunConfig {
            dataset: dataset.into(),
            tile_size: s,
            batch: 32,
            engine,
            ..RunConfig::default()
        };
        let vref = m.vref.clone();
        let coord = Coordinator::new(&cfg, lut, &m, &vref, p).unwrap();
        let (txs, tys) = d.gather(&split.test);
        (coord, txs, tys)
    }

    #[test]
    fn native_serving_classifies_whole_test_set() {
        let (mut coord, txs, _tys) = build(EngineKind::Native, "iris", 16);
        assert_eq!(coord.backend_name(), "native");
        assert_eq!(coord.n_banks(), 1);
        // Single bank: no fan-out pool even under parallel dispatch.
        assert!(!coord.bank_parallel());
        let got = coord.classify_all(&txs).unwrap();
        assert_eq!(got.len(), txs.len());
        assert!(got.iter().all(|c| c.is_some()));
        assert_eq!(coord.metrics.decisions, txs.len() as u64);
        assert!(coord.metrics.energy_per_dec() > 0.0);
        assert_eq!(coord.metrics.n_banks(), 1);
    }

    #[test]
    fn threaded_native_serving_agrees_with_native() {
        let (mut native, txs, _) = build(EngineKind::Native, "haberman", 16);
        let (mut threaded, txs2, _) = build(EngineKind::ThreadedNative, "haberman", 16);
        assert_eq!(txs, txs2);
        assert_eq!(threaded.backend_name(), "threaded-native");
        let a = native.classify_all(&txs).unwrap();
        let b = threaded.classify_all(&txs).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn pjrt_serving_agrees_with_native() {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let (mut native, txs, _) = build(EngineKind::Native, "haberman", 16);
        let (mut pjrt, txs2, _) = build(EngineKind::Pjrt, "haberman", 16);
        assert_eq!(txs, txs2);
        let a = native.classify_all(&txs).unwrap();
        let b = pjrt.classify_all(&txs).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn overdue_partial_batch_releases_on_poll_and_reports_queue_delay() {
        // One request in a width-32 batcher: poll(false) must release it
        // once the 2 ms deadline passes, with NO intervening submit, and
        // the recorded queue delay must be the arrival → dispatch wait
        // (>= max_wait), not the ~0 observed at submission.
        let (mut coord, txs, _) = build(EngineKind::Native, "iris", 16);
        coord.submit(InferenceRequest::new(0, txs[0].clone()));
        // The first poll normally finds the request not yet overdue and
        // releases nothing — but a preempted test thread may already be
        // past the deadline, in which case the batch legitimately
        // releases now (and still only because >= 2 ms elapsed). Either
        // way no second submit ever happens.
        let mut resp = coord.poll(false).unwrap();
        if resp.is_empty() {
            assert_eq!(coord.metrics.queue_delay.count(), 0);
            std::thread::sleep(Duration::from_millis(5));
            resp = coord.poll(false).unwrap();
        }
        assert_eq!(resp.len(), 1, "overdue partial batch must release");
        assert_eq!(resp[0].id, 0);
        assert_eq!(coord.metrics.queue_delay.count(), 1);
        // Release happens only once >= 2 ms (the deadline) has elapsed,
        // and the delay is measured at dispatch — so it must clear
        // max_wait on every path.
        assert!(
            coord.metrics.queue_delay.max() >= 0.002,
            "queue delay {} < max_wait",
            coord.metrics.queue_delay.max()
        );
    }

    #[test]
    fn end_to_end_latency_samples_cover_every_decision() {
        let (mut coord, txs, _) = build(EngineKind::Native, "iris", 16);
        let got = coord.classify_all(&txs).unwrap();
        assert_eq!(coord.metrics.latency_count(), got.len());
        let l = coord.metrics.latency_percentiles().unwrap();
        assert!(l.p50 > 0.0 && l.p50 <= l.p95 && l.p95 <= l.p99);
        // Iris projects all 4 features identically on its single bank.
        assert_eq!(coord.n_features(), txs[0].len());
        assert_eq!(coord.pending(), 0);
    }

    #[test]
    fn batch_deadline_is_retunable() {
        let (mut coord, txs, _) = build(EngineKind::Native, "iris", 16);
        // With an hour-long deadline a lone request never releases on
        // poll(false)...
        coord.set_batch_max_wait(Duration::from_secs(3600));
        coord.submit(InferenceRequest::new(0, txs[0].clone()));
        assert!(coord.poll(false).unwrap().is_empty());
        assert_eq!(coord.pending(), 1);
        // ...until the deadline is retuned to zero.
        coord.set_batch_max_wait(Duration::ZERO);
        assert_eq!(coord.poll(false).unwrap().len(), 1);
    }

    #[test]
    fn responses_preserve_request_ids() {
        let (mut coord, txs, _) = build(EngineKind::Native, "iris", 16);
        for (i, x) in txs.iter().take(5).enumerate() {
            coord.submit(InferenceRequest::new(100 + i as u64, x.clone()));
        }
        let resp = coord.poll(true).unwrap();
        let ids: Vec<u64> = resp.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![100, 101, 102, 103, 104]);
        assert!(resp.iter().all(|r| r.modeled_latency > 0.0));
    }

    // ------------------------------------------------- multi-bank tests

    /// Train the 3-bank bagged forest on haberman and map every bank:
    /// the shared fixture of both coordinator modes.
    fn forest_parts() -> (crate::cart::Forest, Vec<MappedArray>, Vec<Vec<f64>>, Vec<usize>) {
        use crate::cart::{train_forest, ForestParams};
        let mut d = catalog::by_name("haberman", 0xD72CA0).unwrap();
        d.normalize();
        let mut rng = Prng::new(11);
        let split = d.split(0.9, &mut rng);
        let (xs, ys) = d.gather(&split.train);
        let forest = train_forest(
            &xs,
            &ys,
            d.n_classes,
            &ForestParams {
                n_trees: 3,
                sample_fraction: 0.8,
                max_features: 2,
                ..Default::default()
            },
            &mut Prng::new(7),
        );
        let p = DeviceParams::default();
        let arrays: Vec<MappedArray> = forest
            .trees
            .iter()
            .map(|t| MappedArray::from_lut(&compile(t), 16, &p, &mut Prng::new(3)))
            .collect();
        let (txs, tys) = d.gather(&split.test);
        (forest, arrays, txs, tys)
    }

    /// Specs borrow the arrays only during construction; the
    /// coordinator owns everything it needs afterwards.
    fn specs_of<'a>(
        forest: &crate::cart::Forest,
        arrays: &'a [MappedArray],
    ) -> Vec<BankSpec<'a>> {
        forest
            .trees
            .iter()
            .zip(&forest.feature_sets)
            .zip(arrays)
            .map(|((t, feats), m)| {
                let lut = compile(t);
                let rows_physical = lut.n_rows();
                BankSpec {
                    lut,
                    features: feats.clone(),
                    mapped: m,
                    vref: &m.vref,
                    rows_physical,
                }
            })
            .collect()
    }

    /// Build a 3-bank coordinator (bagged forest on haberman) plus the
    /// forest itself and its test split.
    fn build_forest(
        dispatch: BankDispatch,
    ) -> (Coordinator, crate::cart::Forest, Vec<Vec<f64>>, Vec<usize>) {
        let (forest, arrays, txs, tys) = forest_parts();
        let coord = Coordinator::with_banks(
            dispatch,
            16,
            specs_of(&forest, &arrays),
            DeviceParams::default(),
        )
        .unwrap();
        (coord, forest, txs, tys)
    }

    /// Same program behind the streaming pipelined coordinator.
    fn build_forest_pipelined(depth: usize) -> (Coordinator, Vec<Vec<f64>>) {
        use crate::api::NativeBackend;
        use std::sync::Arc;
        let (forest, arrays, txs, _tys) = forest_parts();
        let coord = Coordinator::with_banks_pipelined(
            Arc::new(NativeBackend::new()),
            16,
            specs_of(&forest, &arrays),
            DeviceParams::default(),
            depth,
        )
        .unwrap();
        (coord, txs)
    }

    #[test]
    fn forest_coordinator_votes_match_software_forest() {
        use crate::api::NativeBackend;
        let (mut coord, forest, txs, _tys) =
            build_forest(BankDispatch::Sequential(Box::new(NativeBackend::new())));
        assert_eq!(coord.n_banks(), 3);
        assert!(!coord.bank_parallel());
        let got = coord.classify_all(&txs).unwrap();
        // Ideal hardware: every bank matches its tree exactly, so the
        // combined vote must equal Forest::predict on every input.
        for (i, x) in txs.iter().enumerate() {
            assert_eq!(got[i], Some(forest.predict(x)), "input {i}");
        }
        // Energy is attributed per bank and sums to the aggregate.
        assert_eq!(coord.metrics.n_banks(), 3);
        let sum: f64 = coord.metrics.bank_energy.iter().sum();
        assert!((sum - coord.metrics.modeled_energy).abs() <= 1e-18 * sum.abs().max(1.0));
        assert!(coord.metrics.bank_energy.iter().all(|&e| e > 0.0));
    }

    #[test]
    fn parallel_and_sequential_bank_dispatch_agree() {
        use crate::api::{NativeBackend, ThreadedNativeBackend};
        use std::sync::Arc;
        let (mut seq, _, txs, _) =
            build_forest(BankDispatch::Sequential(Box::new(NativeBackend::new())));
        let (mut par, _, txs2, _) =
            build_forest(BankDispatch::Parallel(Arc::new(NativeBackend::new())));
        let (mut par_threaded, _, _, _) = build_forest(BankDispatch::Parallel(Arc::new(
            ThreadedNativeBackend::new(2),
        )));
        assert_eq!(txs, txs2);
        assert!(par.bank_parallel());
        let a = seq.classify_all(&txs).unwrap();
        let b = par.classify_all(&txs).unwrap();
        let c = par_threaded.classify_all(&txs).unwrap();
        assert_eq!(a, b, "parallel fan-out must not change any vote");
        assert_eq!(a, c);
        // Cost roll-ups are dispatch-invariant too.
        assert_eq!(seq.metrics.modeled_energy, par.metrics.modeled_energy);
        assert_eq!(seq.metrics.active_row_evals, par.metrics.active_row_evals);
        assert_eq!(seq.metrics.bank_energy, par.metrics.bank_energy);
    }

    #[test]
    fn with_banks_rejects_mismatched_class_spaces() {
        use crate::api::NativeBackend;
        let build_one = |name: &str| {
            let mut d = catalog::by_name(name, 1).unwrap();
            d.normalize();
            let tree = train(&d.features, &d.labels, d.n_classes, &TrainParams::default());
            let lut = compile(&tree);
            let m = MappedArray::from_lut(&lut, 16, &DeviceParams::default(), &mut Prng::new(2));
            (lut, m)
        };
        let (lut_a, m_a) = build_one("iris"); // 3 classes
        let (lut_b, m_b) = build_one("haberman"); // 2 classes
        let specs = vec![
            BankSpec {
                features: (0..lut_a.encoders.len()).collect(),
                rows_physical: lut_a.n_rows(),
                lut: lut_a,
                mapped: &m_a,
                vref: &m_a.vref,
            },
            BankSpec {
                features: (0..lut_b.encoders.len()).collect(),
                rows_physical: lut_b.n_rows(),
                lut: lut_b,
                mapped: &m_b,
                vref: &m_b.vref,
            },
        ];
        let err = Coordinator::with_banks(
            BankDispatch::Sequential(Box::new(NativeBackend::new())),
            8,
            specs,
            DeviceParams::default(),
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("class space"), "{err:#}");
    }

    #[test]
    fn pipelined_coordinator_is_bit_identical_to_sequential_on_a_forest() {
        use crate::api::NativeBackend;
        for depth in [1usize, 2, 4] {
            // Fresh sequential coordinator per depth: metrics roll-ups
            // are compared 1:1 against each pipelined run.
            let (mut seq, _, txs, _) =
                build_forest(BankDispatch::Sequential(Box::new(NativeBackend::new())));
            let (mut piped, txs2) = build_forest_pipelined(depth);
            assert_eq!(txs, txs2);
            assert!(piped.pipelined());
            assert!(!seq.pipelined());
            assert_eq!(piped.n_banks(), 3);
            let a = seq.classify_all(&txs).unwrap();
            let b = piped.classify_all(&txs).unwrap();
            assert_eq!(a, b, "depth {depth}: pipelined votes diverged");
            assert_eq!(piped.in_flight(), 0, "drain must empty the pipeline");
            assert_eq!(piped.pending(), 0);
            // Hardware cost roll-ups are execution-strategy-invariant,
            // bit for bit.
            assert_eq!(seq.metrics.modeled_energy, piped.metrics.modeled_energy);
            assert_eq!(seq.metrics.active_row_evals, piped.metrics.active_row_evals);
            assert_eq!(seq.metrics.bank_energy, piped.metrics.bank_energy);
            assert_eq!(seq.metrics.decisions, piped.metrics.decisions);
            assert_eq!(seq.metrics.no_match, piped.metrics.no_match);
            assert_eq!(seq.metrics.multi_match, piped.metrics.multi_match);
            // The pipelined mode reports the paper's modeled figure.
            assert!(piped.metrics.modeled_pipe_throughput > 0.0);
            assert!(piped.metrics.summary_line().contains("modeled-pipe="));
            assert_eq!(seq.metrics.modeled_pipe_throughput, 0.0);
            // Every request got exactly one latency sample.
            assert_eq!(piped.metrics.latency_count(), txs.len());
        }
    }

    #[test]
    fn pipelined_single_bank_matches_sequential_and_drains_on_force() {
        use crate::api::NativeBackend;
        use std::sync::Arc;
        let mut d = catalog::by_name("iris", 0xD72CA0).unwrap();
        d.normalize();
        let tree = train(&d.features, &d.labels, d.n_classes, &TrainParams::default());
        let lut = compile(&tree);
        let p = DeviceParams::default();
        let m = MappedArray::from_lut(&lut, 16, &p, &mut Prng::new(2));
        let spec = || {
            vec![BankSpec {
                lut: lut.clone(),
                features: (0..lut.encoders.len()).collect(),
                mapped: &m,
                vref: &m.vref,
                rows_physical: lut.n_rows(),
            }]
        };
        let mut seq = Coordinator::with_banks(
            BankDispatch::Sequential(Box::new(NativeBackend::new())),
            8,
            spec(),
            p.clone(),
        )
        .unwrap();
        let mut piped = Coordinator::with_banks_pipelined(
            Arc::new(NativeBackend::new()),
            8,
            spec(),
            p.clone(),
            1,
        )
        .unwrap();
        assert_eq!(piped.n_banks(), 1);
        assert!(!piped.bank_parallel(), "one bank needs no fan-out pool");
        let a = seq.classify_all(&d.features[..40].to_vec()).unwrap();
        let b = piped.classify_all(&d.features[..40].to_vec()).unwrap();
        assert_eq!(a, b);

        // A lone request behind an hour-long deadline is only released
        // — and pushed through the whole pipeline — by a forced poll.
        piped.set_batch_max_wait(Duration::from_secs(3600));
        piped.submit(InferenceRequest::new(99, d.features[0].clone()));
        assert!(piped.poll(false).unwrap().is_empty());
        assert_eq!(piped.pending(), 1);
        let resp = piped.poll(true).unwrap();
        assert_eq!(resp.len(), 1);
        assert_eq!(resp[0].id, 99);
        assert!(resp[0].error.is_none());
        assert_eq!(piped.in_flight(), 0);
    }

    #[test]
    fn attached_tracer_records_batch_spans_for_traced_requests() {
        use crate::obs::SpanKind as K;
        let (mut coord, txs, _) = build(EngineKind::Native, "iris", 16);
        let (format, banks, rows) = coord.identity();
        assert_eq!(format, "dt2cam-mapped-program");
        assert_eq!(banks, 1);
        assert!(rows > 0);
        let tracer = crate::obs::Tracer::new(1);
        coord.attach_tracer(tracer.clone());
        for (i, x) in txs.iter().take(3).enumerate() {
            let t = tracer.admit();
            assert_ne!(t, 0, "sample divisor 1 traces everything");
            coord.submit(InferenceRequest::traced(i as u64, x.clone(), t));
        }
        let resp = coord.poll(true).unwrap();
        assert_eq!(resp.len(), 3);
        assert!(resp.iter().all(|r| r.trace != 0), "responses echo the trace id");
        let spans = tracer.snapshot();
        let count = |k: K| spans.iter().filter(|s| s.kind == k).count();
        // One batch: a queue span per request, one dispatch, one bank
        // match (single bank), one vote.
        assert_eq!(count(K::Queue), 3);
        assert_eq!(count(K::Dispatch), 1);
        assert_eq!(count(K::BankMatch), 1);
        assert_eq!(count(K::Vote), 1);
        assert!(spans
            .iter()
            .filter(|s| s.kind == K::BankMatch)
            .all(|s| s.bank == 0));
        // Untraced serving records nothing more once the ring is read.
        let before = tracer.snapshot().len();
        coord.submit(InferenceRequest::new(99, txs[0].clone()));
        let _ = coord.poll(true).unwrap();
        assert_eq!(tracer.snapshot().len(), before);
    }

    #[test]
    fn pipelined_tracer_records_one_stage_span_per_bank_division() {
        use crate::obs::SpanKind as K;
        let (mut coord, txs) = build_forest_pipelined(2);
        let tracer = crate::obs::Tracer::new(1);
        coord.attach_tracer(tracer.clone());
        let t = tracer.admit();
        coord.submit(InferenceRequest::traced(0, txs[0].clone(), t));
        let resp = coord.poll(true).unwrap();
        assert_eq!(resp.len(), 1);
        assert_eq!(resp[0].trace, t);
        let spans = tracer.snapshot();
        let stages: Vec<_> = spans.iter().filter(|s| s.kind == K::Stage).collect();
        let expected: usize = coord.bank_plans().map(|p| p.n_cwd).sum();
        assert_eq!(stages.len(), expected, "one stage span per (bank, division)");
        assert!(stages.iter().all(|s| s.trace == t));
        // Every (bank, division) pair appears exactly once.
        let mut keys: Vec<(u32, u32)> = stages.iter().map(|s| (s.bank, s.division)).collect();
        keys.sort_unstable();
        keys.dedup();
        assert_eq!(keys.len(), expected);
        assert_eq!(spans.iter().filter(|s| s.kind == K::Vote).count(), 1);
        assert_eq!(spans.iter().filter(|s| s.kind == K::Dispatch).count(), 1);
    }

    #[test]
    fn forest_modeled_latency_is_slowest_bank_plus_vote() {
        use crate::api::NativeBackend;
        use crate::synth::latency::vote_latency;
        let (coord, _, _, _) =
            build_forest(BankDispatch::Sequential(Box::new(NativeBackend::new())));
        let slowest = coord
            .bank_plans()
            .map(|p| p.timing.latency)
            .fold(0.0f64, f64::max);
        let p = DeviceParams::default();
        assert!((coord.modeled_latency() - (slowest + vote_latency(&p))).abs() < 1e-24);
        // Single-bank coordinators report the bank's latency unchanged.
        let (single, _, _) = build(EngineKind::Native, "iris", 16);
        assert_eq!(single.modeled_latency(), single.plan().timing.latency);
    }

    // ------------------------------------------------- lifecycle tests

    /// A second, single-bank tenant (iris, 4 features — the forest
    /// fixture's haberman rows have 3) loadable next to the boot
    /// program. Returns its pieces plus its valid input rows.
    fn iris_parts() -> (Lut, MappedArray, Vec<Vec<f64>>) {
        let mut d = catalog::by_name("iris", 0xD72CA0).unwrap();
        d.normalize();
        let tree = train(&d.features, &d.labels, d.n_classes, &TrainParams::default());
        let lut = compile(&tree);
        let m = MappedArray::from_lut(&lut, 16, &DeviceParams::default(), &mut Prng::new(2));
        (lut, m, d.features)
    }

    fn iris_spec<'a>(lut: &Lut, m: &'a MappedArray) -> Vec<BankSpec<'a>> {
        vec![BankSpec {
            features: (0..lut.encoders.len()).collect(),
            rows_physical: lut.n_rows(),
            lut: lut.clone(),
            mapped: m,
            vref: &m.vref,
        }]
    }

    #[test]
    fn load_activate_and_pin_programs() {
        use crate::api::NativeBackend;
        let (mut coord, forest, txs, _) =
            build_forest(BankDispatch::Sequential(Box::new(NativeBackend::new())));
        assert_eq!(coord.active_program(), DEFAULT_PROGRAM);
        assert_eq!(coord.program_list().len(), 1);

        let (lut, m, rows) = iris_parts();
        let v = coord
            .load_program("iris", iris_spec(&lut, &m), 1, lut.n_rows() as u64)
            .unwrap();
        assert_eq!(v, 2, "boot program is version 1; first load stamps 2");

        // Unpinned traffic still serves the boot program; a pin reaches
        // the resident-but-inactive tenant.
        coord.submit(InferenceRequest::new(0, txs[0].clone()));
        coord.submit(InferenceRequest::new(1, rows[0].clone()).with_program(Some("iris".into())));
        let mut resp = coord.poll(true).unwrap();
        resp.sort_by_key(|r| r.id);
        assert_eq!(resp.len(), 2);
        assert!(resp.iter().all(|r| r.error.is_none()));
        assert_eq!((resp[0].program.as_str(), resp[0].version), (DEFAULT_PROGRAM, 1));
        assert_eq!(resp[0].class, Some(forest.predict(&txs[0])));
        assert_eq!((resp[1].program.as_str(), resp[1].version), ("iris", 2));
        assert!(resp[1].class.is_some());

        // Per-program attribution: one decision each, energy > 0.
        let usage = |c: &Coordinator, id: &str| {
            c.metrics.per_program.iter().find(|u| u.id == id).cloned().unwrap()
        };
        assert_eq!(usage(&coord, DEFAULT_PROGRAM).decisions, 1);
        assert_eq!(usage(&coord, "iris").decisions, 1);
        assert!(usage(&coord, "iris").modeled_energy > 0.0);

        // Activation flips only the routing of future unpinned submits.
        coord.activate_program("iris").unwrap();
        assert_eq!(coord.active_program(), "iris");
        assert_eq!(coord.n_banks(), 1, "active-program accessors follow the flip");
        coord.submit(InferenceRequest::new(2, rows[1].clone()));
        let resp = coord.poll(true).unwrap();
        assert_eq!((resp[0].program.as_str(), resp[0].version), ("iris", 2));
        // The old tenant stays resident and pinnable after the swap.
        coord.submit(
            InferenceRequest::new(3, txs[0].clone()).with_program(Some(DEFAULT_PROGRAM.into())),
        );
        let resp = coord.poll(true).unwrap();
        assert_eq!(resp[0].class, Some(forest.predict(&txs[0])));
        let listed = coord.program_list();
        assert_eq!(listed.len(), 2);
        assert!(listed.iter().any(|p| p.id == "iris" && p.active && p.version == 2));
        assert!(listed.iter().any(|p| p.id == DEFAULT_PROGRAM && !p.active && p.banks == 3));
        assert!(listed.iter().all(|p| p.in_flight == 0), "everything drained");
    }

    #[test]
    fn unknown_pin_and_short_features_answer_typed_errors() {
        use crate::api::NativeBackend;
        let (mut coord, _, txs, _) =
            build_forest(BankDispatch::Sequential(Box::new(NativeBackend::new())));
        coord.submit(InferenceRequest::new(7, txs[0].clone()).with_program(Some("ghost".into())));
        let resp = coord.poll(false).unwrap();
        assert_eq!(resp.len(), 1, "refusals drain without any due batch");
        assert_eq!(resp[0].id, 7);
        assert!(resp[0].class.is_none());
        let msg = resp[0].error.clone().unwrap();
        assert!(msg.contains("ghost"), "refusal names the pin: {msg}");
        // A vector too short for the pinned tenant (haberman rows carry
        // 3 features; iris projects 4) is refused at admission, not
        // panicked on mid-batch.
        let (lut, m, _) = iris_parts();
        coord
            .load_program("iris", iris_spec(&lut, &m), 1, lut.n_rows() as u64)
            .unwrap();
        coord.submit(InferenceRequest::new(8, txs[0].clone()).with_program(Some("iris".into())));
        let resp = coord.poll(false).unwrap();
        assert_eq!(resp.len(), 1);
        let msg = resp[0].error.clone().unwrap();
        assert!(msg.contains("features"), "{msg}");
        assert_eq!(resp[0].program, "iris");
        // Nothing leaked into the batcher or the in-flight counts.
        assert_eq!(coord.pending(), 0);
        assert!(coord.program_list().iter().all(|p| p.in_flight == 0));
    }

    #[test]
    fn reload_is_refused_while_admitted_requests_are_in_flight() {
        use crate::api::NativeBackend;
        let (mut coord, forest, txs, _) =
            build_forest(BankDispatch::Sequential(Box::new(NativeBackend::new())));
        coord.set_batch_max_wait(Duration::from_secs(3600));
        coord.submit(InferenceRequest::new(0, txs[0].clone()));
        assert_eq!(coord.program_list()[0].in_flight, 1);
        // The admitted request pins version 1 of the boot program: a
        // reload now could run its batch on the wrong bits.
        let (forest2, arrays2, _, _) = forest_parts();
        let err = coord
            .load_program(DEFAULT_PROGRAM, specs_of(&forest2, &arrays2), 3, 0)
            .unwrap_err();
        assert!(format!("{err:#}").contains("in flight"), "{err:#}");
        // Drain; the answer carries the admission-time version.
        coord.set_batch_max_wait(Duration::ZERO);
        let resp = coord.poll(true).unwrap();
        assert_eq!(resp.len(), 1);
        assert_eq!(resp[0].version, 1);
        assert_eq!(resp[0].class, Some(forest.predict(&txs[0])));
        // Now the reload lands with a bumped version and unpinned
        // admissions stamp it.
        let v = coord
            .load_program(DEFAULT_PROGRAM, specs_of(&forest2, &arrays2), 3, 0)
            .unwrap();
        assert_eq!(v, 2);
        coord.submit(InferenceRequest::new(1, txs[0].clone()));
        let resp = coord.poll(true).unwrap();
        assert_eq!(resp[0].version, 2);
        assert_eq!(resp[0].class, Some(forest2.predict(&txs[0])));
    }

    #[test]
    fn pipelined_registry_serves_both_tenants_with_isolated_pipelines() {
        use crate::api::NativeBackend;
        let (mut piped, txs) = build_forest_pipelined(2);
        let (lut, m, rows) = iris_parts();
        piped
            .load_program("iris", iris_spec(&lut, &m), 1, lut.n_rows() as u64)
            .unwrap();
        // Reference classes from a fresh single-tenant coordinator.
        let mut solo = Coordinator::with_backend(
            Box::new(NativeBackend::new()),
            16,
            lut.clone(),
            &m,
            &m.vref,
            DeviceParams::default(),
        )
        .unwrap();
        let want_iris = solo.classify_all(&rows[..20].to_vec()).unwrap();
        let (mut seq, _, _, _) =
            build_forest(BankDispatch::Sequential(Box::new(NativeBackend::new())));
        let want_forest = seq.classify_all(&txs).unwrap();
        // Interleave pinned iris traffic with unpinned forest traffic.
        for (i, x) in rows[..20].iter().enumerate() {
            piped.submit(
                InferenceRequest::new(1000 + i as u64, x.clone())
                    .with_program(Some("iris".into())),
            );
            piped.submit(InferenceRequest::new(i as u64, txs[i % txs.len()].clone()));
        }
        let mut resp = piped.poll(true).unwrap();
        assert_eq!(resp.len(), 40);
        assert!(resp.iter().all(|r| r.error.is_none()));
        resp.sort_by_key(|r| r.id);
        for (i, want) in want_iris.iter().enumerate() {
            let r = &resp[20 + i];
            assert_eq!(r.id, 1000 + i as u64);
            assert_eq!(r.program, "iris");
            assert_eq!(r.class, *want, "pinned tenant must match solo serving");
        }
        for (i, r) in resp[..20].iter().enumerate() {
            assert_eq!(r.program, DEFAULT_PROGRAM);
            assert_eq!(r.class, want_forest[i % txs.len()]);
        }
        assert_eq!(piped.in_flight(), 0, "drain empties every tenant's pipeline");
    }
}
