//! The coordinator: ties batcher + scheduler + metrics into a serving
//! loop over one pluggable [`MatchBackend`]. This is the `dt2cam serve`
//! engine, the substance of [`crate::api::Session`], and the heart of
//! the `serve_e2e` example.

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::api::backend::MatchBackend;
use crate::api::registry::{self, BackendOptions};
use crate::compiler::Lut;
use crate::config::RunConfig;
use crate::synth::mapping::MappedArray;
use crate::tcam::params::DeviceParams;

use super::batcher::{Batcher, InferenceRequest};
use super::metrics::Metrics;
use super::plan::ServingPlan;
use super::scheduler::{BatchScratch, Scheduler};

/// One answered request.
#[derive(Clone, Debug)]
pub struct InferenceResponse {
    pub id: u64,
    /// Predicted class (None = no surviving row under faults).
    pub class: Option<usize>,
    /// Modeled per-decision latency of the hardware (s).
    pub modeled_latency: f64,
}

/// The serving coordinator. Owns the plan and the match backend;
/// single-threaded facade (the PJRT backend is `!Send`), with row-tile
/// parallelism inside the backend.
pub struct Coordinator {
    plan: ServingPlan,
    lut: Lut,
    padded_width: usize,
    params: DeviceParams,
    backend: Box<dyn MatchBackend>,
    batcher: Batcher,
    /// Scheduler scratch reused across every batch this coordinator
    /// serves — the division walk allocates nothing after warm-up.
    scratch: BatchScratch,
    pub metrics: Metrics,
}

impl Coordinator {
    /// Build a coordinator from prepared pieces, constructing the backend
    /// from the config's engine through the registry. For `pjrt` the
    /// artifact directory must contain a tile/division set matching
    /// `cfg.tile_size` and `cfg.batch` (`make artifacts`).
    pub fn new(
        cfg: &RunConfig,
        lut: Lut,
        mapped: &MappedArray,
        vref: &[f64],
        params: DeviceParams,
    ) -> Result<Coordinator> {
        let backend = registry::create(cfg.engine, &BackendOptions::from_config(cfg))?;
        Self::with_backend(backend, cfg.batch, lut, mapped, vref, params)
    }

    /// Build a coordinator over an already-constructed backend. The
    /// backend is warmed against the plan geometry (fail fast).
    pub fn with_backend(
        backend: Box<dyn MatchBackend>,
        batch: usize,
        lut: Lut,
        mapped: &MappedArray,
        vref: &[f64],
        params: DeviceParams,
    ) -> Result<Coordinator> {
        let plan = ServingPlan::build(mapped, vref, &params);
        // A backend reused across sessions (plan rebuilds after fault
        // injection) must not alias stale per-plan caches.
        backend.invalidate();
        backend.warm(&plan, batch)?;
        Ok(Coordinator {
            plan,
            lut,
            padded_width: mapped.padded_width,
            params,
            backend,
            batcher: Batcher::new(batch, Duration::from_millis(2)),
            scratch: BatchScratch::default(),
            metrics: Metrics::new(),
        })
    }

    pub fn plan(&self) -> &ServingPlan {
        &self.plan
    }

    /// Registry name of the backend driving this coordinator.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Enqueue one request. The queueing delay is *not* recorded here —
    /// at submission the request has waited ~0; [`Coordinator::poll`]
    /// records the real arrival → batch-dispatch delay when the batcher
    /// releases the request.
    pub fn submit(&mut self, req: InferenceRequest) {
        self.metrics.record_request();
        self.batcher.push(req);
    }

    /// Run all due batches; returns responses (request order within batch
    /// preserved). `force_flush` drains partial batches (end of stream).
    pub fn poll(&mut self, force_flush: bool) -> Result<Vec<InferenceResponse>> {
        let mut batches = Vec::new();
        while let Some(b) = self.batcher.next_batch(Instant::now()) {
            batches.push(b);
        }
        if force_flush {
            batches.extend(self.batcher.flush());
        }
        let mut responses = Vec::new();
        for batch in batches {
            responses.extend(self.run_batch(batch)?);
        }
        Ok(responses)
    }

    fn run_batch(&mut self, batch: Vec<InferenceRequest>) -> Result<Vec<InferenceResponse>> {
        let width = self.batcher.batch_width();
        let real = batch.len();
        // The queue delay is measured here, at batch dispatch: this is
        // the full batcher wait (arrival → drain), which a deadline-
        // released partial batch reports as >= max_wait.
        for r in &batch {
            self.metrics.record_queue_delay(r.arrived.elapsed());
        }
        // Encode + pad lanes to the artifact width.
        let mut queries: Vec<Vec<bool>> = batch
            .iter()
            .map(|r| self.plan.encode(&self.lut, self.padded_width, &r.features))
            .collect();
        while queries.len() < width {
            queries.push(vec![false; self.padded_width]);
        }

        let sched = Scheduler::new(&self.plan, &self.params);
        let t0 = Instant::now();
        let out =
            sched.run_batch_with(self.backend.as_ref(), &queries, real, &mut self.scratch)?;
        let wall = t0.elapsed();
        self.metrics.record_batch(
            real,
            out.modeled_energy,
            out.active_row_evals,
            out.no_match,
            out.multi_match,
            wall,
        );
        self.metrics.wall_total += wall.as_secs_f64();

        Ok(batch
            .iter()
            .zip(&out.classes)
            .map(|(req, &class)| InferenceResponse {
                id: req.id,
                class,
                modeled_latency: self.plan.timing.latency,
            })
            .collect())
    }

    /// Convenience: synchronous classification of a whole test set in
    /// batch-width chunks (examples + benches).
    pub fn classify_all(&mut self, inputs: &[Vec<f64>]) -> Result<Vec<Option<usize>>> {
        let mut out = Vec::with_capacity(inputs.len());
        for (i, x) in inputs.iter().enumerate() {
            self.submit(InferenceRequest::new(i as u64, x.clone()));
            let resp = self.poll(false)?;
            out.extend(resp.into_iter().map(|r| (r.id, r.class)));
        }
        out.extend(
            self.poll(true)?
                .into_iter()
                .map(|r| (r.id, r.class)),
        );
        let mut sorted = out;
        sorted.sort_by_key(|(id, _)| *id);
        Ok(sorted.into_iter().map(|(_, c)| c).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cart::{train, TrainParams};
    use crate::compiler::compile;
    use crate::config::EngineKind;
    use crate::dataset::catalog;
    use crate::util::prng::Prng;

    fn build(
        engine: EngineKind,
        dataset: &str,
        s: usize,
    ) -> (Coordinator, Vec<Vec<f64>>, Vec<usize>) {
        let mut d = catalog::by_name(dataset, 0xD72CA0).unwrap();
        d.normalize();
        let mut rng = Prng::new(11);
        let split = d.split(0.9, &mut rng);
        let (xs, ys) = d.gather(&split.train);
        let tree = train(&xs, &ys, d.n_classes, &TrainParams::default());
        let lut = compile(&tree);
        let p = DeviceParams::default();
        let m = MappedArray::from_lut(&lut, s, &p, &mut rng);
        let cfg = RunConfig {
            dataset: dataset.into(),
            tile_size: s,
            batch: 32,
            engine,
            ..RunConfig::default()
        };
        let vref = m.vref.clone();
        let coord = Coordinator::new(&cfg, lut, &m, &vref, p).unwrap();
        let (txs, tys) = d.gather(&split.test);
        (coord, txs, tys)
    }

    #[test]
    fn native_serving_classifies_whole_test_set() {
        let (mut coord, txs, _tys) = build(EngineKind::Native, "iris", 16);
        assert_eq!(coord.backend_name(), "native");
        let got = coord.classify_all(&txs).unwrap();
        assert_eq!(got.len(), txs.len());
        assert!(got.iter().all(|c| c.is_some()));
        assert_eq!(coord.metrics.decisions, txs.len() as u64);
        assert!(coord.metrics.energy_per_dec() > 0.0);
    }

    #[test]
    fn threaded_native_serving_agrees_with_native() {
        let (mut native, txs, _) = build(EngineKind::Native, "haberman", 16);
        let (mut threaded, txs2, _) = build(EngineKind::ThreadedNative, "haberman", 16);
        assert_eq!(txs, txs2);
        assert_eq!(threaded.backend_name(), "threaded-native");
        let a = native.classify_all(&txs).unwrap();
        let b = threaded.classify_all(&txs).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn pjrt_serving_agrees_with_native() {
        if !std::path::Path::new("artifacts/manifest.json").exists() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let (mut native, txs, _) = build(EngineKind::Native, "haberman", 16);
        let (mut pjrt, txs2, _) = build(EngineKind::Pjrt, "haberman", 16);
        assert_eq!(txs, txs2);
        let a = native.classify_all(&txs).unwrap();
        let b = pjrt.classify_all(&txs).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn overdue_partial_batch_releases_on_poll_and_reports_queue_delay() {
        // One request in a width-32 batcher: poll(false) must release it
        // once the 2 ms deadline passes, with NO intervening submit, and
        // the recorded queue delay must be the arrival → dispatch wait
        // (>= max_wait), not the ~0 observed at submission.
        let (mut coord, txs, _) = build(EngineKind::Native, "iris", 16);
        coord.submit(InferenceRequest::new(0, txs[0].clone()));
        // The first poll normally finds the request not yet overdue and
        // releases nothing — but a preempted test thread may already be
        // past the deadline, in which case the batch legitimately
        // releases now (and still only because >= 2 ms elapsed). Either
        // way no second submit ever happens.
        let mut resp = coord.poll(false).unwrap();
        if resp.is_empty() {
            assert_eq!(coord.metrics.queue_delay.count(), 0);
            std::thread::sleep(Duration::from_millis(5));
            resp = coord.poll(false).unwrap();
        }
        assert_eq!(resp.len(), 1, "overdue partial batch must release");
        assert_eq!(resp[0].id, 0);
        assert_eq!(coord.metrics.queue_delay.count(), 1);
        // Release happens only once >= 2 ms (the deadline) has elapsed,
        // and the delay is measured at dispatch — so it must clear
        // max_wait on every path.
        assert!(
            coord.metrics.queue_delay.max() >= 0.002,
            "queue delay {} < max_wait",
            coord.metrics.queue_delay.max()
        );
    }

    #[test]
    fn responses_preserve_request_ids() {
        let (mut coord, txs, _) = build(EngineKind::Native, "iris", 16);
        for (i, x) in txs.iter().take(5).enumerate() {
            coord.submit(InferenceRequest::new(100 + i as u64, x.clone()));
        }
        let resp = coord.poll(true).unwrap();
        let ids: Vec<u64> = resp.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![100, 101, 102, 103, 104]);
        assert!(resp.iter().all(|r| r.modeled_latency > 0.0));
    }
}
