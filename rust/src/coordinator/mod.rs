//! L3 serving coordinator — the system that puts DT2CAM on a request path.
//!
//! vLLM-router-shaped: requests (feature vectors) enter through the
//! [`batcher`], the [`server`] coordinator fans each batch out across the
//! program's CAM **banks** (one per ensemble tree; single-tree programs
//! are the 1-bank case) and combines surviving classes by deterministic
//! majority vote, the [`scheduler`] walks each bank's batch across the
//! column-wise divisions with selective-precharge semantics (Fig 4/5) —
//! per-lane survivor sets are packed [`crate::util::rowmask::RowMask`]
//! bitsets, folded by word-wise AND and popcounted for energy —
//! executing every row-wise tile per division, and [`metrics`] accounts
//! both the *modeled* hardware cost (nJ/dec summed over banks, ns/dec of
//! the slowest bank + vote) and the *wall-clock* cost of this software
//! incarnation.
//!
//! Tile matches are evaluated through the pluggable
//! [`MatchBackend`](crate::api::MatchBackend) seam — `native`,
//! `threaded-native`, and `pjrt` backends register in
//! [`crate::api::registry`], and every layer here compiles only against
//! `&dyn MatchBackend`.
//!
//! [`pipeline`] implements the paper's pipelined mode (Table VI "P" rows)
//! as a first-class execution strategy: a [`StreamingPipeline`] runs one
//! thread per column division *per bank*, connected by bounded channels,
//! over any `Send + Sync` backend — and
//! [`Coordinator::with_banks_pipelined`] plugs it in behind the same
//! `submit`/`poll` seam the batch-sequential coordinator serves, so the
//! socket server and the CLI pick the strategy with a flag. Stage
//! failures are typed ([`StageError`]) and poison only their own batch.

//! [`registry`] holds the online program lifecycle: an LRU-bounded
//! multi-tenant [`ProgramRegistry`] (one active id, monotonic versions)
//! behind [`Coordinator::load_program`] / `activate_program` — batches
//! are keyed by `(program, version)` at admission, so activation is
//! atomic at the admission point and a swap never mixes two programs'
//! rows in one batch.

pub mod batcher;
pub mod metrics;
pub mod pipeline;
pub mod plan;
pub mod registry;
pub mod scheduler;
pub mod server;

pub use batcher::{BatchKey, Batcher, InferenceRequest};
pub use metrics::{LatencyPercentiles, Metrics, ProgramUsage};
pub use pipeline::{run_pipeline, PipeOutcome, StageError, StreamingPipeline};
pub use plan::ServingPlan;
pub use registry::{ProgramRegistry, ProgramSlot};
pub use scheduler::{BatchOutcome, BatchScratch, Scheduler};
pub use server::{
    BankSpec, Coordinator, InferenceResponse, ProgramStatus, DEFAULT_MAX_PROGRAMS, DEFAULT_PROGRAM,
};
