//! Serving plan: everything the hot path needs, precomputed.
//!
//! Built once from a compiled LUT + mapped array (+ any injected faults):
//! per-division conductance buffers in the artifact's `[T, 2S, S]` layout,
//! f32 reference-voltage buffers, T_opt/C_in scalars, and the input
//! encoder. Building W here keeps the request path allocation-free and
//! makes fault injection a plan-rebuild, never a recompile.

use crate::compiler::Lut;
use crate::synth::mapping::MappedArray;
use crate::tcam::cell::Cell;
use crate::tcam::params::DeviceParams;
use crate::util::rowmask::RowMask;

/// Per column-division precomputed buffers.
#[derive(Clone, Debug)]
pub struct DivisionPlan {
    /// Stacked conductances `[n_rwd, 2S, S]` (artifact W layout).
    pub w: Vec<f32>,
    /// Stacked per-row references `[n_rwd, S]` — row-tile r's slice covers
    /// padded rows `r*S .. (r+1)*S` of this division.
    pub vref: Vec<f32>,
    /// T_opt / C_in for this division.
    pub toc: f32,
    /// Log-domain match thresholds (§Perf): `V > vref` with
    /// `V = VDD·e^(−toc·G)` is equivalent to `G < −ln(vref/VDD)/toc`, so
    /// the native hot path compares conductance sums against this
    /// precomputed per-row bound and never calls `exp`. Same layout as
    /// `vref`; `+inf` where `vref <= 0` (always match).
    pub gthresh: Vec<f32>,
}

/// The full plan.
#[derive(Clone, Debug)]
pub struct ServingPlan {
    /// Unique id (per build) — keys the engine's device-buffer cache.
    pub plan_id: u64,
    /// Which CAM bank of the program this plan serves (0 for single-tree
    /// programs; forest programs build one plan per bank). Stamped onto
    /// every [`BatchOutcome`](super::scheduler::BatchOutcome) so bank
    /// results stay attributable after a parallel fan-out.
    pub bank: usize,
    pub s: usize,
    pub n_rwd: usize,
    pub n_cwd: usize,
    pub padded_rows: usize,
    pub real_rows: usize,
    pub divisions: Vec<DivisionPlan>,
    /// Class per padded row.
    pub classes: Vec<usize>,
    pub n_classes: usize,
    /// Rows initially enabled (rogue rows gated out).
    pub initially_active: usize,
    /// Modeled timing (from the synthesizer's device model).
    pub timing: crate::synth::latency::TimingReport,
    /// Modeled per-active-row energy + class-read energy.
    pub e_row: f64,
    pub e_mem: f64,
}

impl ServingPlan {
    /// Precompute the plan from a mapped array. `vref` is the (possibly
    /// variability-perturbed) per-(division, row) reference vector.
    /// Single-bank convenience for [`ServingPlan::build_bank`] (bank 0).
    pub fn build(m: &MappedArray, vref: &[f64], p: &DeviceParams) -> ServingPlan {
        Self::build_bank(m, vref, p, 0)
    }

    /// Build the plan for one bank of a (possibly multi-bank) program.
    pub fn build_bank(
        m: &MappedArray,
        vref: &[f64],
        p: &DeviceParams,
        bank: usize,
    ) -> ServingPlan {
        assert_eq!(vref.len(), m.n_cwd * m.padded_rows);
        let s = m.s;
        let mut divisions = Vec::with_capacity(m.n_cwd);
        for (d, div) in m.divisions.iter().enumerate() {
            let mut w = vec![0.0f32; m.n_rwd * 2 * s * s];
            let mut vr = vec![0.0f32; m.n_rwd * s];
            for rt in 0..m.n_rwd {
                let w_tile = &mut w[rt * 2 * s * s..(rt + 1) * 2 * s * s];
                for local_r in 0..s {
                    let r = rt * s + local_r;
                    let base = r * m.padded_width;
                    for (local_c, c) in (div.col_start..div.col_end).enumerate() {
                        let cell = Cell::from_byte(m.cells[base + c]);
                        // W[2j+b][row] within the tile, row-major [2S, S].
                        w_tile[(2 * local_c) * s + local_r] =
                            cell.g_active(false, p) as f32;
                        w_tile[(2 * local_c + 1) * s + local_r] =
                            cell.g_active(true, p) as f32;
                    }
                    vr[rt * s + local_r] = vref[d * m.padded_rows + r] as f32;
                }
            }
            let toc = (div.t_sense / p.c_in) as f32;
            let gthresh = vr
                .iter()
                .map(|&v| {
                    if v <= 0.0 {
                        f32::INFINITY
                    } else {
                        -((v as f64 / p.vdd).ln() as f32) / toc
                    }
                })
                .collect();
            divisions.push(DivisionPlan {
                w,
                vref: vr,
                toc,
                gthresh,
            });
        }
        static NEXT_PLAN_ID: std::sync::atomic::AtomicU64 =
            std::sync::atomic::AtomicU64::new(1);
        ServingPlan {
            plan_id: NEXT_PLAN_ID.fetch_add(1, std::sync::atomic::Ordering::Relaxed),
            bank,
            s,
            n_rwd: m.n_rwd,
            n_cwd: m.n_cwd,
            padded_rows: m.padded_rows,
            real_rows: m.real_rows,
            divisions,
            classes: m.classes.clone(),
            n_classes: m.n_classes,
            initially_active: m.initially_active_rows(),
            timing: crate::synth::latency::timing(m, p),
            e_row: p.e_row_active(),
            e_mem: p.e_mem,
        }
    }

    /// Encode one feature vector into the padded one-hot Q row
    /// (`[2S * n_cwd]` split per division at execution time): returns the
    /// padded query *bits* (the per-division Q rows are bit slices).
    pub fn encode(&self, lut: &Lut, m_padded_width: usize, x: &[f64]) -> Vec<bool> {
        let mut q = Vec::with_capacity(m_padded_width);
        q.push(false); // decoder bit
        for (e, &v) in lut.encoders.iter().zip(x) {
            q.extend(e.encode_input(v));
        }
        q.resize(m_padded_width, false);
        q
    }

    /// Memory footprint of the precomputed W buffers (bytes).
    pub fn w_bytes(&self) -> usize {
        self.divisions.iter().map(|d| d.w.len() * 4).sum()
    }

    /// Number of pipeline stages this plan serves as: one per column
    /// division (the streaming pipeline spawns exactly this many stage
    /// threads per bank).
    pub fn n_stages(&self) -> usize {
        self.n_cwd
    }

    /// Modeled pipelined throughput of this bank (dec/s, Table VI "P"
    /// rows: `f_max / II`, independent of the division count).
    pub fn pipe_throughput(&self) -> f64 {
        self.timing.throughput_pipe
    }

    /// Fresh per-lane selective-precharge mask: the first
    /// `initially_active` (non-rogue) rows enabled, packed.
    pub fn initial_mask(&self) -> RowMask {
        RowMask::with_prefix(self.padded_rows, self.initially_active)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cart::{train, TrainParams};
    use crate::compiler::compile;
    use crate::dataset::iris;
    use crate::tcam::sim::{self, TileView};
    use crate::util::prng::Prng;

    fn setup() -> (MappedArray, Lut, DeviceParams) {
        let d = iris::load();
        let lut = compile(&train(
            &d.features,
            &d.labels,
            d.n_classes,
            &TrainParams::default(),
        ));
        let p = DeviceParams::default();
        let mut rng = Prng::new(5);
        let m = MappedArray::from_lut(&lut, 16, &p, &mut rng);
        (m, lut, p)
    }

    #[test]
    fn plan_w_matches_sim_conductance_matrix() {
        let (m, _lut, p) = setup();
        let plan = ServingPlan::build(&m, &m.vref, &p);
        // Compare division 0, row tile 0 against a TileView window.
        let div = &m.divisions[0];
        let vref_d = vec![div.vref_nominal; m.padded_rows];
        let view = TileView {
            cells: &m.cells,
            rows: m.s,
            cols: m.s,
            row_stride: m.padded_width,
            row_offset: 0,
            col_offset: div.col_start,
            vref: &vref_d,
            t_opt_over_c: div.t_sense / p.c_in,
        };
        let w_ref = sim::conductance_matrix(&view, &p);
        assert_eq!(&plan.divisions[0].w[..w_ref.len()], &w_ref[..]);
    }

    #[test]
    fn plan_dimensions() {
        let (m, _lut, p) = setup();
        let plan = ServingPlan::build(&m, &m.vref, &p);
        assert_eq!(plan.divisions.len(), m.n_cwd);
        for d in &plan.divisions {
            assert_eq!(d.w.len(), m.n_rwd * 2 * m.s * m.s);
            assert_eq!(d.vref.len(), m.n_rwd * m.s);
            assert!(d.toc > 0.0);
        }
        assert_eq!(plan.initially_active, m.real_rows);
        assert!(plan.w_bytes() > 0);
        assert_eq!(plan.n_stages(), m.n_cwd);
        assert_eq!(plan.pipe_throughput(), plan.timing.throughput_pipe);
        assert!(plan.pipe_throughput() > 0.0);
        let mask = plan.initial_mask();
        assert_eq!(mask.len(), plan.padded_rows);
        assert_eq!(mask.count_ones(), plan.initially_active);
        assert_eq!(mask.first_one(), Some(0));
    }

    #[test]
    fn encode_matches_mapping_pad_query() {
        let (m, lut, p) = setup();
        let plan = ServingPlan::build(&m, &m.vref, &p);
        let x = [5.1, 3.5, 1.4, 0.2];
        let a = plan.encode(&lut, m.padded_width, &x);
        let b = m.pad_query(&lut.encode_input(&x));
        assert_eq!(a, b);
    }
}
