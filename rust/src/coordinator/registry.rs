//! The program registry: the multi-tenant slot table behind online
//! program lifecycle (`dt2cam load` / `activate` / `programs`).
//!
//! An LRU-bounded map of program id → per-program runtime, with one
//! **active** id and a monotonic version counter. The registry itself
//! is generic over the runtime payload `T` (the coordinator stores its
//! per-program bank runtimes + pipeline state; tests store plain
//! values) so the lifecycle invariants are testable in isolation:
//!
//! * **Versioning** — every successful insert stamps a fresh, strictly
//!   increasing version (Risingwave-style catalog versioning): a batch
//!   admitted under `(id, version)` can always detect a reload.
//! * **Atomic activation** — [`ProgramRegistry::activate`] flips one
//!   index; requests admitted before the flip finish on their stamped
//!   slot, requests admitted after route to the new one. There is no
//!   drain: both slots stay resident and serveable.
//! * **Pinned safety** — eviction considers only slots that are neither
//!   active nor carrying in-flight requests; when every slot is
//!   protected, insertion is refused with a typed error instead of
//!   evicting work out from under an admitted request.
//! * **Reload safety** — re-inserting a resident id bumps its version
//!   in place, but only when the slot has nothing in flight; otherwise
//!   a stamped batch could silently run on the wrong program bits.

use anyhow::Result;

/// One resident program.
pub struct ProgramSlot<T> {
    /// Program id (client-chosen; `"default"` for the boot program).
    pub id: String,
    /// Registry-wide monotonic version stamped at insert.
    pub version: u64,
    /// The per-program runtime payload.
    pub runtime: T,
    /// Logical LRU clock value of the last touch.
    last_used: u64,
    /// Requests admitted against this slot and not yet answered.
    in_flight: u64,
}

impl<T> ProgramSlot<T> {
    /// Requests admitted against this slot and not yet answered.
    pub fn in_flight(&self) -> u64 {
        self.in_flight
    }
}

/// LRU-bounded program table with one active id and monotonic
/// versions. See the module docs for the invariants.
pub struct ProgramRegistry<T> {
    slots: Vec<ProgramSlot<T>>,
    /// Index of the active slot in `slots`.
    active: usize,
    /// Next version to stamp (starts at 1; never reused).
    next_version: u64,
    /// Logical LRU clock (bumped on every touch).
    clock: u64,
    cap: usize,
}

impl<T> ProgramRegistry<T> {
    /// A registry holding (and activating) one boot program, bounded at
    /// `cap` resident programs (clamped to >= 1).
    pub fn new(cap: usize, id: &str, runtime: T) -> ProgramRegistry<T> {
        ProgramRegistry {
            slots: vec![ProgramSlot {
                id: id.to_string(),
                version: 1,
                runtime,
                last_used: 0,
                in_flight: 0,
            }],
            active: 0,
            next_version: 2,
            clock: 1,
            cap: cap.max(1),
        }
    }

    /// Resident program count.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// Maximum resident programs.
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Retune the bound (clamped to >= 1). Shrinking below the current
    /// resident count evicts nothing immediately — the next insert
    /// evicts (or refuses) until the table fits.
    pub fn set_cap(&mut self, cap: usize) {
        self.cap = cap.max(1);
    }

    /// Every resident slot (registry order, not LRU order).
    pub fn slots(&self) -> &[ProgramSlot<T>] {
        &self.slots
    }

    /// Every resident slot, mutably (the coordinator's pipelined poll
    /// sweeps every resident pipeline).
    pub fn slots_mut(&mut self) -> &mut [ProgramSlot<T>] {
        &mut self.slots
    }

    /// Index of `id`, if resident.
    pub fn index_of(&self, id: &str) -> Option<usize> {
        self.slots.iter().position(|s| s.id == id)
    }

    /// Resolve an optional pin to a slot index: `Some(id)` must be
    /// resident (else `None` is returned and the caller refuses the
    /// request), `None` follows the active id.
    pub fn resolve(&self, pin: Option<&str>) -> Option<usize> {
        match pin {
            Some(id) => self.index_of(id),
            None => Some(self.active),
        }
    }

    /// The slot at `idx` (indices come from [`ProgramRegistry::resolve`]
    /// / [`ProgramRegistry::index_of`] and are stable between mutations).
    pub fn slot(&self, idx: usize) -> &ProgramSlot<T> {
        &self.slots[idx]
    }

    /// The slot at `idx`, mutably.
    pub fn slot_mut(&mut self, idx: usize) -> &mut ProgramSlot<T> {
        &mut self.slots[idx]
    }

    /// The active slot.
    pub fn active_slot(&self) -> &ProgramSlot<T> {
        &self.slots[self.active]
    }

    /// The active slot, mutably.
    pub fn active_slot_mut(&mut self) -> &mut ProgramSlot<T> {
        &mut self.slots[self.active]
    }

    /// The active program id.
    pub fn active_id(&self) -> &str {
        &self.slots[self.active].id
    }

    /// Mark `idx` as just-used (LRU bookkeeping) and count one admitted
    /// request against it. Paired with [`ProgramRegistry::finish`].
    pub fn begin(&mut self, idx: usize, n: u64) {
        self.clock += 1;
        let slot = &mut self.slots[idx];
        slot.last_used = self.clock;
        slot.in_flight += n;
    }

    /// Retire `n` answered requests from program `id`. Saturating — a
    /// slot evicted and re-inserted between admit and answer (only
    /// possible at in_flight 0 by construction) must not underflow.
    pub fn finish(&mut self, id: &str, n: u64) {
        if let Some(i) = self.index_of(id) {
            self.slots[i].in_flight = self.slots[i].in_flight.saturating_sub(n);
        }
    }

    /// Make `id` the target of all unpinned admissions. Atomic at the
    /// admission point: nothing about resident slots changes, only the
    /// routing of *future* submits. Returns the activated version.
    pub fn activate(&mut self, id: &str) -> Result<u64> {
        let Some(i) = self.index_of(id) else {
            anyhow::bail!(
                "cannot activate unknown program {id:?} (resident: {:?})",
                self.ids()
            );
        };
        self.active = i;
        self.clock += 1;
        self.slots[i].last_used = self.clock;
        Ok(self.slots[i].version)
    }

    /// Insert (or reload) a program and stamp a fresh version, which is
    /// returned. A resident id is replaced in place — refused while it
    /// has requests in flight. A full registry evicts the
    /// least-recently-used slot that is neither active nor carrying
    /// in-flight requests; when every slot is protected the insert is
    /// refused with a typed error (never evicts admitted work).
    pub fn insert(&mut self, id: &str, runtime: T) -> Result<u64> {
        if let Some(i) = self.index_of(id) {
            let slot = &mut self.slots[i];
            anyhow::ensure!(
                slot.in_flight == 0,
                "cannot reload program {id:?}: {} requests in flight against \
                 version {} — retry once drained, or load under a new id",
                slot.in_flight,
                slot.version
            );
            let version = self.next_version;
            self.next_version += 1;
            self.clock += 1;
            let slot = &mut self.slots[i];
            slot.runtime = runtime;
            slot.version = version;
            slot.last_used = self.clock;
            return Ok(version);
        }
        while self.slots.len() >= self.cap {
            let victim = self
                .slots
                .iter()
                .enumerate()
                .filter(|(i, s)| *i != self.active && s.in_flight == 0)
                .min_by_key(|(_, s)| s.last_used)
                .map(|(i, _)| i);
            let Some(victim) = victim else {
                anyhow::bail!(
                    "program registry is full ({} of {}) and every resident program \
                     is active or has requests in flight — cannot load {id:?}",
                    self.slots.len(),
                    self.cap
                );
            };
            let evicted = self.slots.remove(victim);
            drop(evicted);
            // The active index may have shifted down by the removal.
            if victim < self.active {
                self.active -= 1;
            }
        }
        let version = self.next_version;
        self.next_version += 1;
        self.clock += 1;
        self.slots.push(ProgramSlot {
            id: id.to_string(),
            version,
            runtime,
            last_used: self.clock,
            in_flight: 0,
        });
        Ok(version)
    }

    /// Resident program ids (registry order).
    pub fn ids(&self) -> Vec<&str> {
        self.slots.iter().map(|s| s.id.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn boot_program_is_active_at_version_one() {
        let r = ProgramRegistry::new(4, "default", 10);
        assert_eq!(r.len(), 1);
        assert_eq!(r.active_id(), "default");
        assert_eq!(r.active_slot().version, 1);
        assert_eq!(r.active_slot().runtime, 10);
        assert_eq!(r.resolve(None), Some(0));
        assert_eq!(r.resolve(Some("default")), Some(0));
        assert_eq!(r.resolve(Some("missing")), None);
    }

    #[test]
    fn versions_are_monotonic_across_inserts_and_reloads() {
        let mut r = ProgramRegistry::new(4, "a", 0);
        assert_eq!(r.insert("b", 1).unwrap(), 2);
        assert_eq!(r.insert("c", 2).unwrap(), 3);
        // Reload in place: same id, fresh version, new runtime.
        assert_eq!(r.insert("b", 9).unwrap(), 4);
        let b = r.slot(r.index_of("b").unwrap());
        assert_eq!((b.version, b.runtime), (4, 9));
        // The active program never changed.
        assert_eq!(r.active_id(), "a");
    }

    #[test]
    fn activation_flips_routing_only() {
        let mut r = ProgramRegistry::new(4, "a", 0);
        r.insert("b", 1).unwrap();
        assert_eq!(r.activate("b").unwrap(), 2);
        assert_eq!(r.active_id(), "b");
        assert_eq!(r.resolve(None), r.index_of("b"));
        // Both programs stay resident and pinnable.
        assert_eq!(r.resolve(Some("a")), r.index_of("a"));
        let err = r.activate("zzz").unwrap_err();
        assert!(format!("{err:#}").contains("unknown program"), "{err:#}");
        assert_eq!(r.active_id(), "b", "failed activation changes nothing");
    }

    #[test]
    fn lru_eviction_picks_least_recently_used_idle_slot() {
        let mut r = ProgramRegistry::new(3, "a", 0);
        r.insert("b", 1).unwrap();
        r.insert("c", 2).unwrap();
        // Touch b after c: a is LRU among non-active… but a is active,
        // so the eviction order considers b and c only. Touch b, making
        // c the victim.
        let b = r.index_of("b").unwrap();
        r.begin(b, 1);
        r.finish("b", 1);
        r.insert("d", 3).unwrap();
        assert_eq!(r.len(), 3);
        assert!(r.index_of("c").is_none(), "c was LRU and idle");
        assert!(r.index_of("a").is_some(), "active is never evicted");
        assert!(r.index_of("b").is_some());
        assert!(r.index_of("d").is_some());
        assert_eq!(r.active_id(), "a", "eviction must not move the active id");
    }

    #[test]
    fn eviction_never_touches_active_or_in_flight_slots() {
        let mut r = ProgramRegistry::new(2, "a", 0);
        r.insert("b", 1).unwrap();
        // Pin b with one in-flight request: both slots are now
        // protected (a active, b in flight) — insert must refuse.
        let b = r.index_of("b").unwrap();
        r.begin(b, 1);
        let err = r.insert("c", 2).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("registry is full"), "{msg}");
        assert!(msg.contains("\"c\""), "refusal names the program: {msg}");
        assert_eq!(r.len(), 2, "refused insert leaves the registry untouched");
        // Drain b; now it is evictable and the insert succeeds.
        r.finish("b", 1);
        r.insert("c", 2).unwrap();
        assert!(r.index_of("b").is_none());
        assert!(r.index_of("c").is_some());
    }

    #[test]
    fn reload_refused_while_requests_in_flight() {
        let mut r = ProgramRegistry::new(4, "a", 0);
        r.insert("b", 1).unwrap();
        let b = r.index_of("b").unwrap();
        r.begin(b, 2);
        let err = r.insert("b", 9).unwrap_err();
        assert!(format!("{err:#}").contains("2 requests in flight"), "{err:#}");
        // Untouched: old version, old runtime.
        let slot = r.slot(r.index_of("b").unwrap());
        assert_eq!((slot.version, slot.runtime), (2, 1));
        r.finish("b", 2);
        assert_eq!(r.insert("b", 9).unwrap(), 3);
    }

    #[test]
    fn eviction_preserves_the_active_index() {
        // Active slot sits *after* the victim in the vec: removal must
        // re-point the active index, not silently activate a neighbor.
        let mut r = ProgramRegistry::new(2, "a", 0);
        r.insert("b", 1).unwrap();
        r.activate("b").unwrap();
        // a is now idle and LRU; inserting c evicts it. b (active)
        // shifted down one index.
        r.insert("c", 2).unwrap();
        assert_eq!(r.active_id(), "b");
        assert!(r.index_of("a").is_none());
        r.begin(r.resolve(None).unwrap(), 1);
        assert_eq!(r.active_slot().in_flight(), 1);
    }

    #[test]
    fn finish_is_saturating_and_ignores_unknown_ids() {
        let mut r = ProgramRegistry::new(2, "a", 0);
        r.finish("a", 5);
        assert_eq!(r.active_slot().in_flight(), 0);
        r.finish("ghost", 1);
    }
}
