//! Serving metrics: modeled hardware cost + wall-clock software cost.

use std::time::Duration;

use crate::obs::Histogram;
use crate::util::stats::{percentile, OnlineStats};

/// Bound on retained end-to-end latency samples: percentiles are
/// computed over a sliding window of the most recent requests, so a
/// long-lived server's memory stays flat.
pub const LATENCY_WINDOW: usize = 1 << 16;

/// End-to-end latency percentiles (s) over the retained sample window.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LatencyPercentiles {
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

/// Per-program share of a multi-tenant serving run: how many decisions
/// each resident program answered and the modeled energy its banks
/// burned doing so. The aggregate fields on [`Metrics`] are the sums;
/// this breakdown is what makes A/B serving of two forest variants
/// observable (`dt2cam programs`, `MetricsSnapshot::per_program`).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ProgramUsage {
    /// Program id as loaded (`"default"` for the boot program).
    pub id: String,
    /// Decisions answered by this program.
    pub decisions: u64,
    /// Modeled energy total (J) attributed to this program's banks.
    pub modeled_energy: f64,
}

/// Aggregated over a serving run.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub requests: u64,
    pub batches: u64,
    pub decisions: u64,
    pub no_match: u64,
    pub multi_match: u64,
    /// Modeled energy total (J). For a multi-bank (forest) program this
    /// is the sum over banks — see [`Metrics::bank_energy`] for the
    /// per-bank breakdown.
    pub modeled_energy: f64,
    /// Per-bank modeled energy (J); `bank_energy.len()` is the bank
    /// count of the serving coordinator (1 for single-tree programs).
    /// Sums to `modeled_energy`.
    pub bank_energy: Vec<f64>,
    /// Modeled active row-division evaluations.
    pub active_row_evals: u64,
    /// Wall-clock per batch (s).
    pub batch_wall: OnlineStats,
    /// Request queueing delay (s), measured arrival → batch dispatch
    /// (recorded when the batcher releases the request, not at submit —
    /// a deadline-released partial batch reports >= the batcher's
    /// `max_wait`).
    pub queue_delay: OnlineStats,
    /// Total serving wall time (s).
    pub wall_total: f64,
    /// Modeled pipelined throughput of the served program (dec/s,
    /// Table VI "P" rows: `f_max / pipeline_ii_cycles`, the slowest
    /// bank's figure for forests). Set by the pipelined coordinator at
    /// construction; 0 for batch-sequential serving, where the figure
    /// would be aspirational rather than descriptive.
    pub modeled_pipe_throughput: f64,
    /// Batches that a pipeline stage failed (the typed
    /// [`StageError`](super::pipeline::StageError) travels to the
    /// caller on every affected response; this is the roll-up).
    pub stage_errors: u64,
    /// Logical rows across all served banks (what the searcher models:
    /// every bank's full row table, shared rows counted once per
    /// owner). Set at coordinator construction; 0 when unknown.
    pub rows_total: u64,
    /// Physically stored rows across all served banks after row
    /// optimization (shared row blocks counted once, at their canonical
    /// owner). Equal to `rows_total` for unoptimized programs.
    pub rows_physical: u64,
    /// End-to-end per-request latency samples (s): arrival → response
    /// materialization, i.e. queue delay *plus* batch service. Ring of
    /// the most recent [`LATENCY_WINDOW`] requests.
    latency_samples: Vec<f64>,
    /// Ring write cursor into `latency_samples`.
    latency_next: usize,
    /// Mergeable end-to-end latency histogram (ns) over the *whole*
    /// run (histograms never slide — bucket counts stay exact, which
    /// is what makes cluster-wide percentile merging exact).
    pub latency_hist: Histogram,
    /// Mergeable queue-delay histogram (ns).
    pub queue_hist: Histogram,
    /// Real lanes per dispatched batch.
    pub batch_hist: Histogram,
    /// Per-program decision/energy attribution, in first-use order.
    /// Single-program serving shows exactly one entry (the boot
    /// program); hot-swap and pinned tenants grow it.
    pub per_program: Vec<ProgramUsage>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_batch(
        &mut self,
        real_lanes: usize,
        modeled_energy: f64,
        active_rows: u64,
        no_match: usize,
        multi_match: usize,
        wall: Duration,
    ) {
        self.batches += 1;
        self.decisions += real_lanes as u64;
        self.modeled_energy += modeled_energy;
        self.active_row_evals += active_rows;
        self.no_match += no_match as u64;
        self.multi_match += multi_match as u64;
        self.batch_wall.push(wall.as_secs_f64());
        self.batch_hist.record(real_lanes as u64);
    }

    /// Count one arrival (at submit; the delay is not yet known).
    pub fn record_request(&mut self) {
        self.requests += 1;
    }

    /// Attribute one bank's share of a batch's modeled energy (the
    /// aggregate is still recorded through [`Metrics::record_batch`];
    /// this keeps the per-bank breakdown for forest observability).
    pub fn record_bank_energy(&mut self, bank: usize, energy: f64) {
        if self.bank_energy.len() <= bank {
            self.bank_energy.resize(bank + 1, 0.0);
        }
        self.bank_energy[bank] += energy;
    }

    /// Number of CAM banks this serving run dispatched to (1 for
    /// single-tree programs; 0 before any batch ran).
    pub fn n_banks(&self) -> usize {
        self.bank_energy.len()
    }

    /// Attribute one batch's decisions + modeled energy to the program
    /// that served it (the aggregate is still recorded through
    /// [`Metrics::record_batch`]; this keeps the per-tenant breakdown).
    pub fn record_program(&mut self, id: &str, decisions: u64, modeled_energy: f64) {
        match self.per_program.iter_mut().find(|p| p.id == id) {
            Some(p) => {
                p.decisions += decisions;
                p.modeled_energy += modeled_energy;
            }
            None => self.per_program.push(ProgramUsage {
                id: id.to_string(),
                decisions,
                modeled_energy,
            }),
        }
    }

    /// Record one request's arrival → batch-dispatch wait (at drain).
    pub fn record_queue_delay(&mut self, queue_delay: Duration) {
        self.queue_delay.push(queue_delay.as_secs_f64());
        self.queue_hist.record(queue_delay.as_nanos() as u64);
    }

    /// Record one request's end-to-end latency (arrival → response
    /// materialization: queue delay + batch service). Feeds the
    /// p50/p95/p99 roll-ups in [`Metrics::latency_percentiles`].
    pub fn record_latency(&mut self, total: Duration) {
        let x = total.as_secs_f64();
        if self.latency_samples.len() < LATENCY_WINDOW {
            self.latency_samples.push(x);
        } else {
            self.latency_samples[self.latency_next] = x;
        }
        self.latency_next = (self.latency_next + 1) % LATENCY_WINDOW;
        self.latency_hist.record(total.as_nanos() as u64);
    }

    /// Retained end-to-end latency samples (bounded by
    /// [`LATENCY_WINDOW`]).
    pub fn latency_count(&self) -> usize {
        self.latency_samples.len()
    }

    /// p50/p95/p99 end-to-end latency over the retained window; `None`
    /// before the first request completes.
    pub fn latency_percentiles(&self) -> Option<LatencyPercentiles> {
        if self.latency_samples.is_empty() {
            return None;
        }
        let mut sorted = self.latency_samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Some(LatencyPercentiles {
            p50: percentile(&sorted, 50.0),
            p95: percentile(&sorted, 95.0),
            p99: percentile(&sorted, 99.0),
        })
    }

    /// Modeled energy per decision (J).
    pub fn energy_per_dec(&self) -> f64 {
        if self.decisions == 0 {
            0.0
        } else {
            self.modeled_energy / self.decisions as f64
        }
    }

    /// Wall-clock decisions per second of this software incarnation.
    pub fn wall_throughput(&self) -> f64 {
        if self.wall_total > 0.0 {
            self.decisions as f64 / self.wall_total
        } else {
            0.0
        }
    }

    /// One-line summary for logs.
    pub fn summary_line(&self) -> String {
        let banks = if self.bank_energy.len() > 1 {
            format!(" banks={}", self.bank_energy.len())
        } else {
            String::new()
        };
        let lat = match self.latency_percentiles() {
            Some(l) => format!(
                " lat(p50/p95/p99)={:.1}/{:.1}/{:.1} us",
                l.p50 * 1e6,
                l.p95 * 1e6,
                l.p99 * 1e6
            ),
            None => String::new(),
        };
        // The modeled pipelined figure (f_max/3) rides alongside the
        // wall number so the gap toward the paper's Table VI rows is
        // visible in every serving log line of the pipelined mode.
        let pipe = if self.modeled_pipe_throughput > 0.0 {
            format!(" modeled-pipe={:.3e} dec/s", self.modeled_pipe_throughput)
        } else {
            String::new()
        };
        let stage_errs = if self.stage_errors > 0 {
            format!(" stage_errors={}", self.stage_errors)
        } else {
            String::new()
        };
        // Physical vs logical row storage: diverges only for
        // row-optimized artifacts (shared blocks / merged rows), so the
        // segment is silent until a coordinator stamps the counts.
        let rows = if self.rows_total > 0 {
            format!(" rows={}/{}", self.rows_physical, self.rows_total)
        } else {
            String::new()
        };
        // Multi-tenant runs break decisions down per program; a
        // single-program run's breakdown is the aggregate, so the
        // segment stays silent then.
        let programs = if self.per_program.len() > 1 {
            let parts: Vec<String> = self
                .per_program
                .iter()
                .map(|p| format!("{}:{}", p.id, p.decisions))
                .collect();
            format!(" programs={}", parts.join(","))
        } else {
            String::new()
        };
        format!(
            "requests={} decisions={} batches={} e/dec={:.3} nJ rows/dec={:.1} \
             wall-throughput={:.0} dec/s{pipe} no_match={} multi_match={}{banks}{rows}{programs}{lat}{stage_errs}",
            self.requests,
            self.decisions,
            self.batches,
            self.energy_per_dec() * 1e9,
            if self.decisions > 0 {
                self.active_row_evals as f64 / self.decisions as f64
            } else {
                0.0
            },
            self.wall_throughput(),
            self.no_match,
            self.multi_match,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let mut m = Metrics::new();
        m.record_request();
        m.record_request();
        m.record_queue_delay(Duration::from_micros(10));
        m.record_queue_delay(Duration::from_micros(20));
        m.record_batch(2, 1e-9, 100, 0, 0, Duration::from_micros(50));
        m.wall_total = 1.0;
        assert_eq!(m.requests, 2);
        assert_eq!(m.decisions, 2);
        assert_eq!(m.queue_delay.count(), 2);
        assert!((m.queue_delay.mean() - 15e-6).abs() < 1e-12);
        assert!((m.energy_per_dec() - 0.5e-9).abs() < 1e-18);
        assert_eq!(m.wall_throughput(), 2.0);
        assert!(m.summary_line().contains("decisions=2"));
    }

    #[test]
    fn empty_is_zero() {
        let m = Metrics::new();
        assert_eq!(m.energy_per_dec(), 0.0);
        assert_eq!(m.wall_throughput(), 0.0);
        assert_eq!(m.n_banks(), 0);
        assert!(m.latency_percentiles().is_none());
        assert!(!m.summary_line().contains("lat(p50/p95/p99)"));
    }

    #[test]
    fn modeled_pipe_throughput_rides_alongside_wall_numbers() {
        let mut m = Metrics::new();
        // Batch-sequential serving never shows the pipelined figure.
        assert!(!m.summary_line().contains("modeled-pipe"));
        assert!(!m.summary_line().contains("stage_errors"));
        m.modeled_pipe_throughput = 3.33e8;
        let line = m.summary_line();
        assert!(line.contains("modeled-pipe=3.330e8 dec/s"), "{line}");
        assert!(line.contains("wall-throughput="), "{line}");
        m.stage_errors = 2;
        assert!(m.summary_line().contains("stage_errors=2"));
    }

    #[test]
    fn row_accounting_rides_alongside_wall_numbers() {
        let mut m = Metrics::new();
        // Coordinators that never stamped row counts stay silent.
        assert!(!m.summary_line().contains("rows="));
        m.rows_total = 120;
        m.rows_physical = 97;
        let line = m.summary_line();
        assert!(line.contains("rows=97/120"), "{line}");
        assert!(line.contains("wall-throughput="), "{line}");
    }

    #[test]
    fn latency_percentiles_over_recorded_samples() {
        let mut m = Metrics::new();
        // 1..=100 ms — p50 = 50.5 ms, p99 = 99.01 ms (linear interp).
        for i in 1..=100u64 {
            m.record_latency(Duration::from_millis(i));
        }
        assert_eq!(m.latency_count(), 100);
        let l = m.latency_percentiles().unwrap();
        assert!((l.p50 - 0.0505).abs() < 1e-9, "{}", l.p50);
        assert!((l.p95 - 0.09505).abs() < 1e-9, "{}", l.p95);
        assert!((l.p99 - 0.09901).abs() < 1e-9, "{}", l.p99);
        assert!(l.p50 <= l.p95 && l.p95 <= l.p99);
        assert!(m.summary_line().contains("lat(p50/p95/p99)"));
    }

    #[test]
    fn histograms_track_latency_queue_and_batch_size() {
        let mut m = Metrics::new();
        m.record_latency(Duration::from_micros(10));
        m.record_latency(Duration::from_micros(100));
        m.record_queue_delay(Duration::from_micros(5));
        m.record_batch(4, 1e-9, 8, 0, 0, Duration::from_micros(50));
        assert_eq!(m.latency_hist.count(), 2);
        assert_eq!(m.latency_hist.sum(), 110_000); // ns
        assert_eq!(m.queue_hist.count(), 1);
        assert_eq!(m.batch_hist.count(), 1);
        assert_eq!(m.batch_hist.sum(), 4);
        // The histogram covers the whole run, not just the sliding
        // percentile window.
        for _ in 0..LATENCY_WINDOW {
            m.record_latency(Duration::from_micros(10));
        }
        assert_eq!(m.latency_count(), LATENCY_WINDOW);
        assert_eq!(m.latency_hist.count() as usize, LATENCY_WINDOW + 2);
    }

    #[test]
    fn latency_window_is_bounded_and_slides() {
        let mut m = Metrics::new();
        for _ in 0..LATENCY_WINDOW + 10 {
            m.record_latency(Duration::from_micros(10));
        }
        assert_eq!(m.latency_count(), LATENCY_WINDOW);
        // After the window slid past the early samples, only the new
        // value remains.
        for _ in 0..LATENCY_WINDOW {
            m.record_latency(Duration::from_micros(20));
        }
        let l = m.latency_percentiles().unwrap();
        assert!((l.p50 - 20e-6).abs() < 1e-12);
        assert!((l.p99 - 20e-6).abs() < 1e-12);
    }

    #[test]
    fn per_program_attribution_accumulates_and_shows_when_multi_tenant() {
        let mut m = Metrics::new();
        assert!(!m.summary_line().contains("programs="));
        m.record_program("A", 3, 3e-9);
        // One program: the breakdown equals the aggregate, stay silent.
        assert!(!m.summary_line().contains("programs="));
        m.record_program("A", 2, 2e-9);
        m.record_program("B", 1, 1e-9);
        assert_eq!(m.per_program.len(), 2);
        assert_eq!(m.per_program[0].id, "A");
        assert_eq!(m.per_program[0].decisions, 5);
        assert!((m.per_program[0].modeled_energy - 5e-9).abs() < 1e-24);
        assert_eq!(m.per_program[1].decisions, 1);
        let line = m.summary_line();
        assert!(line.contains("programs=A:5,B:1"), "{line}");
    }

    #[test]
    fn bank_energy_breakdown_accumulates_per_bank() {
        let mut m = Metrics::new();
        m.record_bank_energy(0, 1e-9);
        m.record_bank_energy(2, 3e-9);
        m.record_bank_energy(0, 1e-9);
        assert_eq!(m.n_banks(), 3);
        assert!((m.bank_energy[0] - 2e-9).abs() < 1e-24);
        assert_eq!(m.bank_energy[1], 0.0);
        assert!((m.bank_energy[2] - 3e-9).abs() < 1e-24);
        // summary mentions the bank count only for multi-bank runs.
        assert!(m.summary_line().contains("banks=3"));
        assert!(!Metrics::new().summary_line().contains("banks="));
    }
}
