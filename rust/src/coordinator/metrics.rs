//! Serving metrics: modeled hardware cost + wall-clock software cost.

use std::time::Duration;

use crate::util::stats::OnlineStats;

/// Aggregated over a serving run.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub requests: u64,
    pub batches: u64,
    pub decisions: u64,
    pub no_match: u64,
    pub multi_match: u64,
    /// Modeled energy total (J). For a multi-bank (forest) program this
    /// is the sum over banks — see [`Metrics::bank_energy`] for the
    /// per-bank breakdown.
    pub modeled_energy: f64,
    /// Per-bank modeled energy (J); `bank_energy.len()` is the bank
    /// count of the serving coordinator (1 for single-tree programs).
    /// Sums to `modeled_energy`.
    pub bank_energy: Vec<f64>,
    /// Modeled active row-division evaluations.
    pub active_row_evals: u64,
    /// Wall-clock per batch (s).
    pub batch_wall: OnlineStats,
    /// Request queueing delay (s), measured arrival → batch dispatch
    /// (recorded when the batcher releases the request, not at submit —
    /// a deadline-released partial batch reports >= the batcher's
    /// `max_wait`).
    pub queue_delay: OnlineStats,
    /// Total serving wall time (s).
    pub wall_total: f64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_batch(
        &mut self,
        real_lanes: usize,
        modeled_energy: f64,
        active_rows: u64,
        no_match: usize,
        multi_match: usize,
        wall: Duration,
    ) {
        self.batches += 1;
        self.decisions += real_lanes as u64;
        self.modeled_energy += modeled_energy;
        self.active_row_evals += active_rows;
        self.no_match += no_match as u64;
        self.multi_match += multi_match as u64;
        self.batch_wall.push(wall.as_secs_f64());
    }

    /// Count one arrival (at submit; the delay is not yet known).
    pub fn record_request(&mut self) {
        self.requests += 1;
    }

    /// Attribute one bank's share of a batch's modeled energy (the
    /// aggregate is still recorded through [`Metrics::record_batch`];
    /// this keeps the per-bank breakdown for forest observability).
    pub fn record_bank_energy(&mut self, bank: usize, energy: f64) {
        if self.bank_energy.len() <= bank {
            self.bank_energy.resize(bank + 1, 0.0);
        }
        self.bank_energy[bank] += energy;
    }

    /// Number of CAM banks this serving run dispatched to (1 for
    /// single-tree programs; 0 before any batch ran).
    pub fn n_banks(&self) -> usize {
        self.bank_energy.len()
    }

    /// Record one request's arrival → batch-dispatch wait (at drain).
    pub fn record_queue_delay(&mut self, queue_delay: Duration) {
        self.queue_delay.push(queue_delay.as_secs_f64());
    }

    /// Modeled energy per decision (J).
    pub fn energy_per_dec(&self) -> f64 {
        if self.decisions == 0 {
            0.0
        } else {
            self.modeled_energy / self.decisions as f64
        }
    }

    /// Wall-clock decisions per second of this software incarnation.
    pub fn wall_throughput(&self) -> f64 {
        if self.wall_total > 0.0 {
            self.decisions as f64 / self.wall_total
        } else {
            0.0
        }
    }

    /// One-line summary for logs.
    pub fn summary_line(&self) -> String {
        let banks = if self.bank_energy.len() > 1 {
            format!(" banks={}", self.bank_energy.len())
        } else {
            String::new()
        };
        format!(
            "requests={} decisions={} batches={} e/dec={:.3} nJ rows/dec={:.1} \
             wall-throughput={:.0} dec/s no_match={} multi_match={}{banks}",
            self.requests,
            self.decisions,
            self.batches,
            self.energy_per_dec() * 1e9,
            if self.decisions > 0 {
                self.active_row_evals as f64 / self.decisions as f64
            } else {
                0.0
            },
            self.wall_throughput(),
            self.no_match,
            self.multi_match,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let mut m = Metrics::new();
        m.record_request();
        m.record_request();
        m.record_queue_delay(Duration::from_micros(10));
        m.record_queue_delay(Duration::from_micros(20));
        m.record_batch(2, 1e-9, 100, 0, 0, Duration::from_micros(50));
        m.wall_total = 1.0;
        assert_eq!(m.requests, 2);
        assert_eq!(m.decisions, 2);
        assert_eq!(m.queue_delay.count(), 2);
        assert!((m.queue_delay.mean() - 15e-6).abs() < 1e-12);
        assert!((m.energy_per_dec() - 0.5e-9).abs() < 1e-18);
        assert_eq!(m.wall_throughput(), 2.0);
        assert!(m.summary_line().contains("decisions=2"));
    }

    #[test]
    fn empty_is_zero() {
        let m = Metrics::new();
        assert_eq!(m.energy_per_dec(), 0.0);
        assert_eq!(m.wall_throughput(), 0.0);
        assert_eq!(m.n_banks(), 0);
    }

    #[test]
    fn bank_energy_breakdown_accumulates_per_bank() {
        let mut m = Metrics::new();
        m.record_bank_energy(0, 1e-9);
        m.record_bank_energy(2, 3e-9);
        m.record_bank_energy(0, 1e-9);
        assert_eq!(m.n_banks(), 3);
        assert!((m.bank_energy[0] - 2e-9).abs() < 1e-24);
        assert_eq!(m.bank_energy[1], 0.0);
        assert!((m.bank_energy[2] - 3e-9).abs() < 1e-24);
        // summary mentions the bank count only for multi-bank runs.
        assert!(m.summary_line().contains("banks=3"));
        assert!(!Metrics::new().summary_line().contains("banks="));
    }
}
