//! Stage scheduler: one batch across all column divisions (Fig 4).
//!
//! Sequential column-wise walk with selective-precharge semantics: a
//! per-lane enable bitmask over the padded rows is ANDed with each
//! division's match results; rows disabled for a lane are not counted as
//! active (energy) in later divisions. Row-wise tiles of a division run in
//! parallel — on the thread pool (native engine) or inside one stacked
//! PJRT call (pjrt engine).

use anyhow::Context;

use crate::runtime::MatchEngine;
use crate::tcam::params::DeviceParams;
use crate::util::threadpool::parallel_map;

use super::plan::ServingPlan;

/// Engine selection for the scheduler (borrowed per call-site).
pub enum EngineRef<'a> {
    /// Native f32 simulator; row tiles fan out over scoped threads.
    Native,
    /// PJRT artifacts (single-threaded engine; XLA's intra-op pool and
    /// the stacked-division artifacts provide the tile parallelism).
    Pjrt(&'a MatchEngine),
}

/// Result of scheduling one batch.
#[derive(Clone, Debug)]
pub struct BatchOutcome {
    /// Predicted class per lane (`None`: dead lane or no survivor).
    pub classes: Vec<Option<usize>>,
    /// Modeled energy total over real lanes (J).
    pub modeled_energy: f64,
    /// Active row-division evaluations (modeled, real lanes only).
    pub active_row_evals: u64,
    pub no_match: usize,
    pub multi_match: usize,
}

/// Scheduler over a prepared plan.
pub struct Scheduler<'a> {
    pub plan: &'a ServingPlan,
    pub params: &'a DeviceParams,
}

/// Match one row tile against a batch, directly from the plan's W layout.
/// Writes `[lane][local_row]` booleans into `out`.
///
/// Two code paths, chosen by activity density (§Perf):
/// * **dense** — the full vectorizable gather-matmul over all S rows per
///   lane (first column division, where every row is still enabled);
/// * **sparse** — per-(lane, enabled-row) scalar evaluation, skipping the
///   rows selective precharge already disabled. In later divisions only a
///   handful of rows per lane survive, so this is orders of magnitude
///   less work (exactly the hardware's SP energy saving, mirrored in
///   software time).
fn tile_match_from_w(
    w_tile: &[f32],
    gthresh_tile: &[f32],
    s: usize,
    lane_bits: &[&[bool]],
    // Enable mask per lane for this tile's rows (`[lane][local_row]`),
    // or None = all enabled.
    enabled: Option<&[&[bool]]>,
    out: &mut [bool],
) {
    debug_assert_eq!(out.len(), lane_bits.len() * s);
    // Count active (lane, row) pairs to pick the path.
    let active: usize = match enabled {
        None => lane_bits.len() * s,
        Some(en) => en.iter().map(|e| e.iter().filter(|&&x| x).count()).sum(),
    };
    let dense_cutoff = lane_bits.len() * s / 8;

    if active >= dense_cutoff || enabled.is_none() {
        // Dense: per lane, one gather-accumulate across all rows.
        let mut g = vec![0.0f32; s];
        for (lane, bits) in lane_bits.iter().enumerate() {
            debug_assert_eq!(bits.len(), s);
            g.iter_mut().for_each(|x| *x = 0.0);
            for (j, &b) in bits.iter().enumerate() {
                let row_w =
                    &w_tile[(2 * j + usize::from(b)) * s..(2 * j + usize::from(b) + 1) * s];
                for (acc, &wv) in g.iter_mut().zip(row_w) {
                    *acc += wv;
                }
            }
            for r in 0..s {
                // Log-domain SA compare: no exp on the hot path.
                out[lane * s + r] = g[r] < gthresh_tile[r];
            }
        }
    } else {
        // Sparse: touch only enabled (lane, row) pairs.
        let en = enabled.expect("sparse path requires masks");
        for (lane, bits) in lane_bits.iter().enumerate() {
            for r in 0..s {
                if !en[lane][r] {
                    continue;
                }
                let mut g = 0.0f32;
                for (j, &b) in bits.iter().enumerate() {
                    g += w_tile[(2 * j + usize::from(b)) * s + r];
                }
                out[lane * s + r] = g < gthresh_tile[r];
            }
        }
    }
}

impl<'a> Scheduler<'a> {
    pub fn new(plan: &'a ServingPlan, params: &'a DeviceParams) -> Scheduler<'a> {
        Scheduler { plan, params }
    }

    /// Execute one batch. `queries[lane]` is the padded query bit-vector
    /// (length `n_cwd * S`); `real_lanes` lanes at the front are live,
    /// the rest are padding. Dead lanes cost no modeled energy (their SAs
    /// are gated like rogue rows).
    pub fn run_batch(
        &self,
        engine: &EngineRef<'_>,
        queries: &[Vec<bool>],
        real_lanes: usize,
    ) -> anyhow::Result<BatchOutcome> {
        let plan = self.plan;
        let s = plan.s;
        let lanes = queries.len();
        assert!(real_lanes <= lanes);
        for q in queries {
            assert_eq!(q.len(), plan.n_cwd * s, "query width mismatch");
        }

        // Per-lane enable mask over padded rows.
        let mut enabled: Vec<Vec<bool>> = (0..lanes)
            .map(|_| {
                let mut v = vec![false; plan.padded_rows];
                v[..plan.initially_active].fill(true);
                v
            })
            .collect();
        let mut energy_rows: u64 = 0;

        for (d, div) in plan.divisions.iter().enumerate() {
            // Modeled energy: active rows of real lanes pay this division.
            for lane_enabled in enabled.iter().take(real_lanes) {
                energy_rows += lane_enabled.iter().filter(|&&e| e).count() as u64;
            }

            // Division query bits per lane.
            let col0 = d * s;
            let lane_bits: Vec<&[bool]> =
                queries.iter().map(|q| &q[col0..col0 + s]).collect();

            // Evaluate all row tiles.
            let matches: Vec<Vec<bool>> = match engine {
                EngineRef::Native => {
                    // [row_tile] -> [lane][local_row]; row-wise tiles in
                    // parallel, like the hardware (Fig 4). After the first
                    // division most rows are SP-disabled, so the per-tile
                    // work collapses to the sparse path and thread fan-out
                    // stops paying — stay serial once activity is low.
                    let div_ref = &plan.divisions[d];
                    let lane_bits_ref = &lane_bits;
                    let enabled_ref = &enabled;
                    let total_active: usize = enabled
                        .iter()
                        .map(|e| e.iter().filter(|&&x| x).count())
                        .sum();
                    let run_tile = move |rt: usize| -> Vec<bool> {
                        let w_tile = &div_ref.w[rt * 2 * s * s..(rt + 1) * 2 * s * s];
                        let gthresh_tile = &div_ref.gthresh[rt * s..(rt + 1) * s];
                        let en_refs: Vec<&[bool]> = enabled_ref
                            .iter()
                            .map(|e| &e[rt * s..(rt + 1) * s])
                            .collect();
                        let mut out = vec![false; lane_bits_ref.len() * s];
                        tile_match_from_w(
                            w_tile,
                            gthresh_tile,
                            s,
                            lane_bits_ref,
                            Some(&en_refs),
                            &mut out,
                        );
                        out
                    };
                    // Thread fan-out only pays past ~8 row tiles: scoped
                    // spawn costs ~30-50 us/thread while a dense 128x128
                    // tile match is ~100-200 us (§Perf measurement).
                    if total_active >= lanes * s && plan.n_rwd >= 8 {
                        let jobs: Vec<usize> = (0..plan.n_rwd).collect();
                        parallel_map(jobs, run_tile)
                    } else {
                        (0..plan.n_rwd).map(run_tile).collect()
                    }
                }
                EngineRef::Pjrt(eng) => {
                    self.run_division_pjrt(eng, d, &lane_bits, lanes)?
                }
            };

            // AND the results into the enable masks.
            for (rt, tile_matches) in matches.iter().enumerate() {
                for lane in 0..lanes {
                    let base = rt * s;
                    let lane_m = &tile_matches[lane * s..(lane + 1) * s];
                    let en = &mut enabled[lane];
                    for r in 0..s {
                        let idx = base + r;
                        en[idx] = en[idx] && lane_m[r];
                    }
                }
            }
            let _ = div;
        }

        // Survivors -> classes.
        let mut classes = Vec::with_capacity(lanes);
        let mut no_match = 0;
        let mut multi_match = 0;
        for (lane, en) in enabled.iter().enumerate() {
            if lane >= real_lanes {
                classes.push(None);
                continue;
            }
            let survivors: Vec<usize> = en
                .iter()
                .enumerate()
                .filter(|(_, &e)| e)
                .map(|(i, _)| i)
                .collect();
            match survivors.len() {
                0 => {
                    no_match += 1;
                    classes.push(None);
                }
                1 => classes.push(Some(plan.classes[survivors[0]])),
                _ => {
                    multi_match += 1;
                    // Priority encoder: lowest row wins.
                    classes.push(Some(plan.classes[survivors[0]]));
                }
            }
        }

        let modeled_energy =
            energy_rows as f64 * plan.e_row + real_lanes as f64 * plan.e_mem;
        Ok(BatchOutcome {
            classes,
            modeled_energy,
            active_row_evals: energy_rows,
            no_match,
            multi_match,
        })
    }

    /// One column division through PJRT, chunking row tiles over the
    /// available stacked-division artifacts (T ∈ {16, 8, 4, 2}) with the
    /// plain tile artifact as the T=1 fallback. Lane counts that were
    /// never lowered are padded up to the nearest available artifact
    /// batch (padding lanes are all-zero one-hots: G = 0, discarded on
    /// the way out).
    fn run_division_pjrt(
        &self,
        eng: &MatchEngine,
        d: usize,
        lane_bits: &[&[bool]],
        lanes: usize,
    ) -> anyhow::Result<Vec<Vec<bool>>> {
        let plan = self.plan;
        let s = plan.s;
        let div = &plan.divisions[d];

        // Artifact batch width: smallest lowered batch >= lanes.
        let pb = eng
            .manifest()
            .best_tile_batch(s, lanes)
            .with_context(|| format!("no artifacts for tile size {s}"))?;
        anyhow::ensure!(
            pb >= lanes,
            "batch {lanes} exceeds the largest lowered artifact batch {pb}              for S={s}; re-run `make artifacts` with a larger BATCH_SIZES"
        );

        // Build the Q buffer once per division: [pb, 2S] one-hot.
        let mut q = vec![0.0f32; pb * 2 * s];
        for (lane, bits) in lane_bits.iter().enumerate() {
            let row = &mut q[lane * 2 * s..(lane + 1) * 2 * s];
            for (j, &b) in bits.iter().enumerate() {
                row[2 * j + usize::from(b)] = 1.0;
            }
        }

        let mut out: Vec<Vec<bool>> = Vec::with_capacity(plan.n_rwd);
        let mut rt = 0usize;
        while rt < plan.n_rwd {
            let remaining = plan.n_rwd - rt;
            // Exact-fit stacked artifact, or — §Perf — the smallest
            // *larger* stack padded with zero-conductance dummy tiles
            // (one PJRT dispatch beats several small ones on CPU; dummy
            // rows read all-match and are dropped below).
            let exact = [16usize, 8, 4, 2]
                .into_iter()
                .find(|&t| t <= remaining && eng.manifest().division(s, pb, t).is_some());
            let padded = [2usize, 4, 8, 16]
                .into_iter()
                .find(|&t| t >= remaining && eng.manifest().division(s, pb, t).is_some());
            // Measured on this CPU (EXPERIMENTS.md §Perf): the stacked
            // artifact's cost grows with T (interpret-mode pallas lowers
            // to a per-tile loop), so exact chunks beat padding — padding
            // is only the fallback when no exact stack exists.
            let (chunk, real) = match (exact, padded) {
                (Some(t), _) => (t, t),
                (None, Some(t)) => (t, remaining.min(t)),
                (None, None) => (1, 1),
            };
            // Device-resident constants: W / vref / toc never change
            // between batches — upload once per (plan, division, range)
            // and execute with buffers (§Perf: removes the dominant
            // per-call host→device copy).
            let bkey = |slot: u64| {
                (plan.plan_id << 32)
                    ^ ((d as u64) << 24)
                    ^ ((rt as u64) << 8)
                    ^ ((chunk as u64) << 2)
                    ^ slot
            };
            use crate::runtime::ArtifactKind;
            let toc_buf = eng.cached_buffer(bkey(2), &[div.toc], &[])?;
            let res = if chunk == 1 {
                let w = &div.w[rt * 2 * s * s..(rt + 1) * 2 * s * s];
                let vr = &div.vref[rt * s..(rt + 1) * s];
                let w_buf = eng.cached_buffer(bkey(0), w, &[2 * s, s])?;
                let v_buf = eng.cached_buffer(bkey(1), vr, &[s])?;
                eng.match_cached(ArtifactKind::Tile, s, pb, 1, &q, &w_buf, &v_buf, &toc_buf)?
            } else if real == chunk {
                let w = &div.w[rt * 2 * s * s..(rt + chunk) * 2 * s * s];
                let vr = &div.vref[rt * s..(rt + chunk) * s];
                let w_buf = eng.cached_buffer(bkey(0), w, &[chunk, 2 * s, s])?;
                let v_buf = eng.cached_buffer(bkey(1), vr, &[chunk, s])?;
                eng.match_cached(
                    ArtifactKind::Division, s, pb, chunk, &q, &w_buf, &v_buf, &toc_buf,
                )?
            } else {
                // Pad the tail with zero-conductance tiles.
                let mut w = vec![0.0f32; chunk * 2 * s * s];
                w[..real * 2 * s * s]
                    .copy_from_slice(&div.w[rt * 2 * s * s..(rt + real) * 2 * s * s]);
                let mut vr = vec![0.5f32; chunk * s];
                vr[..real * s].copy_from_slice(&div.vref[rt * s..(rt + real) * s]);
                let w_buf = eng.cached_buffer(bkey(0), &w, &[chunk, 2 * s, s])?;
                let v_buf = eng.cached_buffer(bkey(1), &vr, &[chunk, s])?;
                eng.match_cached(
                    ArtifactKind::Division, s, pb, chunk, &q, &w_buf, &v_buf, &toc_buf,
                )?
            };
            // res.matched layout: [chunk, pb, s] -> per row tile, keeping
            // only the real lanes and real tiles.
            for t in 0..real {
                let mut tile = vec![false; lanes * s];
                for lane in 0..lanes {
                    for r in 0..s {
                        tile[lane * s + r] =
                            res.matched[t * pb * s + lane * s + r] > 0.5;
                    }
                }
                out.push(tile);
            }
            rt += real;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cart::{train, TrainParams};
    use crate::compiler::{compile, Lut};
    use crate::dataset::{catalog, Dataset};
    use crate::synth::mapping::MappedArray;
    use crate::util::prng::Prng;


    fn setup(name: &str, s: usize) -> (Dataset, Lut, MappedArray, DeviceParams) {
        let mut d = catalog::by_name(name, 0xD72CA0).unwrap();
        d.normalize();
        let (xs, ys) = (&d.features, &d.labels);
        let tree = train(xs, ys, d.n_classes, &TrainParams::default());
        let lut = compile(&tree);
        let p = DeviceParams::default();
        let mut rng = Prng::new(3);
        let m = MappedArray::from_lut(&lut, s, &p, &mut rng);
        (d, lut, m, p)
    }

    #[test]
    fn native_scheduler_matches_lut_classification() {
        let (d, lut, m, p) = setup("iris", 16);
        let plan = ServingPlan::build(&m, &m.vref, &p);
        let sched = Scheduler::new(&plan, &p);
        let engine = EngineRef::Native;

        let queries: Vec<Vec<bool>> = d.features[..32]
            .iter()
            .map(|x| m.pad_query(&lut.encode_input(x)))
            .collect();
        let out = sched.run_batch(&engine, &queries, 32).unwrap();
        assert_eq!(out.no_match, 0);
        assert_eq!(out.multi_match, 0);
        for (i, x) in d.features[..32].iter().enumerate() {
            assert_eq!(out.classes[i], lut.classify(x), "lane {i}");
        }
        assert!(out.modeled_energy > 0.0);
    }

    #[test]
    fn dead_lanes_cost_nothing_and_return_none() {
        let (d, lut, m, p) = setup("iris", 16);
        let plan = ServingPlan::build(&m, &m.vref, &p);
        let sched = Scheduler::new(&plan, &p);
        let engine = EngineRef::Native;

        let mut queries: Vec<Vec<bool>> = d.features[..2]
            .iter()
            .map(|x| m.pad_query(&lut.encode_input(x)))
            .collect();
        queries.push(vec![false; m.padded_width]); // dead lane
        let out_3 = sched.run_batch(&engine, &queries, 2).unwrap();
        assert_eq!(out_3.classes[2], None);

        let out_2 = sched
            .run_batch(&engine, &queries[..2].to_vec(), 2)
            .unwrap();
        assert_eq!(out_3.modeled_energy, out_2.modeled_energy);
    }

    #[test]
    fn multi_division_sp_masks_propagate() {
        // haberman at S=16 has multiple divisions; scheduler must agree
        // with the synthesizer's functional simulation classification.
        let (d, lut, m, p) = setup("haberman", 16);
        assert!(m.n_cwd > 1);
        let plan = ServingPlan::build(&m, &m.vref, &p);
        let sched = Scheduler::new(&plan, &p);
        let engine = EngineRef::Native;

        let queries: Vec<Vec<bool>> = d.features[..16]
            .iter()
            .map(|x| m.pad_query(&lut.encode_input(x)))
            .collect();
        let out = sched.run_batch(&engine, &queries, 16).unwrap();
        for (i, x) in d.features[..16].iter().enumerate() {
            assert_eq!(out.classes[i], lut.classify(x), "lane {i}");
        }
    }

    #[test]
    fn pjrt_and_native_schedulers_agree() {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let eng = MatchEngine::new(&dir).unwrap();
        let (d, lut, m, p) = setup("haberman", 16);
        let plan = ServingPlan::build(&m, &m.vref, &p);
        let sched = Scheduler::new(&plan, &p);

        let queries: Vec<Vec<bool>> = d.features[..32]
            .iter()
            .map(|x| m.pad_query(&lut.encode_input(x)))
            .collect();
        let native = sched
            .run_batch(&EngineRef::Native, &queries, 32)
            .unwrap();
        let pjrt = sched
            .run_batch(&EngineRef::Pjrt(&eng), &queries, 32)
            .unwrap();
        assert_eq!(native.classes, pjrt.classes);
        assert_eq!(native.modeled_energy, pjrt.modeled_energy);
    }
}
