//! Stage scheduler: one batch across all column divisions (Fig 4).
//!
//! Sequential column-wise walk with selective-precharge semantics: a
//! per-lane enable bitmask over the padded rows is ANDed with each
//! division's match results; rows disabled for a lane are not counted as
//! active (energy) in later divisions. Division evaluation is delegated
//! to a pluggable [`MatchBackend`] (native simulator, threaded-native,
//! or PJRT artifacts — see [`crate::api::registry`]); the scheduler owns
//! what the backends must not: mask folding, energy accounting, and the
//! survivor → class priority encoding.

use crate::api::backend::{DivisionRequest, MatchBackend};
use crate::tcam::params::DeviceParams;

use super::plan::ServingPlan;

/// Result of scheduling one batch.
#[derive(Clone, Debug)]
pub struct BatchOutcome {
    /// Predicted class per lane (`None`: dead lane or no survivor).
    pub classes: Vec<Option<usize>>,
    /// Modeled energy total over real lanes (J).
    pub modeled_energy: f64,
    /// Active row-division evaluations (modeled, real lanes only).
    pub active_row_evals: u64,
    pub no_match: usize,
    pub multi_match: usize,
}

/// Scheduler over a prepared plan.
pub struct Scheduler<'a> {
    pub plan: &'a ServingPlan,
    pub params: &'a DeviceParams,
}

impl<'a> Scheduler<'a> {
    pub fn new(plan: &'a ServingPlan, params: &'a DeviceParams) -> Scheduler<'a> {
        Scheduler { plan, params }
    }

    /// Execute one batch. `queries[lane]` is the padded query bit-vector
    /// (length `n_cwd * S`); `real_lanes` lanes at the front are live,
    /// the rest are padding. Dead lanes cost no modeled energy (their SAs
    /// are gated like rogue rows).
    pub fn run_batch(
        &self,
        backend: &dyn MatchBackend,
        queries: &[Vec<bool>],
        real_lanes: usize,
    ) -> anyhow::Result<BatchOutcome> {
        let plan = self.plan;
        let s = plan.s;
        let lanes = queries.len();
        assert!(real_lanes <= lanes);
        for q in queries {
            assert_eq!(q.len(), plan.n_cwd * s, "query width mismatch");
        }

        // Per-lane enable mask over padded rows.
        let mut enabled: Vec<Vec<bool>> = (0..lanes)
            .map(|_| {
                let mut v = vec![false; plan.padded_rows];
                v[..plan.initially_active].fill(true);
                v
            })
            .collect();
        let mut energy_rows: u64 = 0;

        for d in 0..plan.divisions.len() {
            // Modeled energy: active rows of real lanes pay this division.
            for lane_enabled in enabled.iter().take(real_lanes) {
                energy_rows += lane_enabled.iter().filter(|&&e| e).count() as u64;
            }

            // Division query bits per lane.
            let col0 = d * s;
            let lane_bits: Vec<&[bool]> =
                queries.iter().map(|q| &q[col0..col0 + s]).collect();

            // Evaluate all row tiles through the backend.
            let req = DivisionRequest {
                division: d,
                lane_bits: &lane_bits,
                enabled: &enabled,
            };
            let matches = backend.match_division(plan, &req)?;

            // AND the results into the enable masks.
            for (rt, tile_matches) in matches.iter().enumerate() {
                for lane in 0..lanes {
                    let base = rt * s;
                    let lane_m = &tile_matches[lane * s..(lane + 1) * s];
                    let en = &mut enabled[lane];
                    for r in 0..s {
                        let idx = base + r;
                        en[idx] = en[idx] && lane_m[r];
                    }
                }
            }
        }

        // Survivors -> classes.
        let mut classes = Vec::with_capacity(lanes);
        let mut no_match = 0;
        let mut multi_match = 0;
        for (lane, en) in enabled.iter().enumerate() {
            if lane >= real_lanes {
                classes.push(None);
                continue;
            }
            let survivors: Vec<usize> = en
                .iter()
                .enumerate()
                .filter(|(_, &e)| e)
                .map(|(i, _)| i)
                .collect();
            match survivors.len() {
                0 => {
                    no_match += 1;
                    classes.push(None);
                }
                1 => classes.push(Some(plan.classes[survivors[0]])),
                _ => {
                    multi_match += 1;
                    // Priority encoder: lowest row wins.
                    classes.push(Some(plan.classes[survivors[0]]));
                }
            }
        }

        let modeled_energy =
            energy_rows as f64 * plan.e_row + real_lanes as f64 * plan.e_mem;
        Ok(BatchOutcome {
            classes,
            modeled_energy,
            active_row_evals: energy_rows,
            no_match,
            multi_match,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{NativeBackend, PjrtBackend, ThreadedNativeBackend};
    use crate::cart::{train, TrainParams};
    use crate::compiler::{compile, Lut};
    use crate::dataset::{catalog, Dataset};
    use crate::synth::mapping::MappedArray;
    use crate::util::prng::Prng;

    fn setup(name: &str, s: usize) -> (Dataset, Lut, MappedArray, DeviceParams) {
        let mut d = catalog::by_name(name, 0xD72CA0).unwrap();
        d.normalize();
        let (xs, ys) = (&d.features, &d.labels);
        let tree = train(xs, ys, d.n_classes, &TrainParams::default());
        let lut = compile(&tree);
        let p = DeviceParams::default();
        let mut rng = Prng::new(3);
        let m = MappedArray::from_lut(&lut, s, &p, &mut rng);
        (d, lut, m, p)
    }

    #[test]
    fn native_scheduler_matches_lut_classification() {
        let (d, lut, m, p) = setup("iris", 16);
        let plan = ServingPlan::build(&m, &m.vref, &p);
        let sched = Scheduler::new(&plan, &p);
        let backend = NativeBackend::new();

        let queries: Vec<Vec<bool>> = d.features[..32]
            .iter()
            .map(|x| m.pad_query(&lut.encode_input(x)))
            .collect();
        let out = sched.run_batch(&backend, &queries, 32).unwrap();
        assert_eq!(out.no_match, 0);
        assert_eq!(out.multi_match, 0);
        for (i, x) in d.features[..32].iter().enumerate() {
            assert_eq!(out.classes[i], lut.classify(x), "lane {i}");
        }
        assert!(out.modeled_energy > 0.0);
    }

    #[test]
    fn dead_lanes_cost_nothing_and_return_none() {
        let (d, lut, m, p) = setup("iris", 16);
        let plan = ServingPlan::build(&m, &m.vref, &p);
        let sched = Scheduler::new(&plan, &p);
        let backend = NativeBackend::new();

        let mut queries: Vec<Vec<bool>> = d.features[..2]
            .iter()
            .map(|x| m.pad_query(&lut.encode_input(x)))
            .collect();
        queries.push(vec![false; m.padded_width]); // dead lane
        let out_3 = sched.run_batch(&backend, &queries, 2).unwrap();
        assert_eq!(out_3.classes[2], None);

        let out_2 = sched
            .run_batch(&backend, &queries[..2].to_vec(), 2)
            .unwrap();
        assert_eq!(out_3.modeled_energy, out_2.modeled_energy);
    }

    #[test]
    fn multi_division_sp_masks_propagate() {
        // haberman at S=16 has multiple divisions; scheduler must agree
        // with the synthesizer's functional simulation classification.
        let (d, lut, m, p) = setup("haberman", 16);
        assert!(m.n_cwd > 1);
        let plan = ServingPlan::build(&m, &m.vref, &p);
        let sched = Scheduler::new(&plan, &p);
        let backend = NativeBackend::new();

        let queries: Vec<Vec<bool>> = d.features[..16]
            .iter()
            .map(|x| m.pad_query(&lut.encode_input(x)))
            .collect();
        let out = sched.run_batch(&backend, &queries, 16).unwrap();
        for (i, x) in d.features[..16].iter().enumerate() {
            assert_eq!(out.classes[i], lut.classify(x), "lane {i}");
        }
    }

    #[test]
    fn threaded_native_scheduler_agrees_with_native() {
        let (d, lut, m, p) = setup("haberman", 16);
        let plan = ServingPlan::build(&m, &m.vref, &p);
        let sched = Scheduler::new(&plan, &p);

        let queries: Vec<Vec<bool>> = d.features[..24]
            .iter()
            .map(|x| m.pad_query(&lut.encode_input(x)))
            .collect();
        let native = sched
            .run_batch(&NativeBackend::new(), &queries, 24)
            .unwrap();
        let threaded = sched
            .run_batch(&ThreadedNativeBackend::new(4), &queries, 24)
            .unwrap();
        assert_eq!(native.classes, threaded.classes);
        assert_eq!(native.modeled_energy, threaded.modeled_energy);
        assert_eq!(native.active_row_evals, threaded.active_row_evals);
    }

    #[test]
    fn pjrt_and_native_schedulers_agree() {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let pjrt = PjrtBackend::from_dir(&dir).unwrap();
        let (d, lut, m, p) = setup("haberman", 16);
        let plan = ServingPlan::build(&m, &m.vref, &p);
        let sched = Scheduler::new(&plan, &p);

        let queries: Vec<Vec<bool>> = d.features[..32]
            .iter()
            .map(|x| m.pad_query(&lut.encode_input(x)))
            .collect();
        let native = sched
            .run_batch(&NativeBackend::new(), &queries, 32)
            .unwrap();
        let got = sched.run_batch(&pjrt, &queries, 32).unwrap();
        assert_eq!(native.classes, got.classes);
        assert_eq!(native.modeled_energy, got.modeled_energy);
    }
}
