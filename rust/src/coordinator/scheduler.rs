//! Stage scheduler: one batch across all column divisions (Fig 4).
//!
//! Sequential column-wise walk with selective-precharge semantics: a
//! per-lane packed [`RowMask`] over the padded rows is ANDed (word-wise)
//! with each division's match results; rows disabled for a lane are not
//! counted as active (energy) in later divisions, and once *every* real
//! lane's mask is empty the walk stops — the hardware gates all
//! precharge at that point, so the remaining divisions cost nothing.
//! Division evaluation is delegated to a pluggable [`MatchBackend`]
//! (native simulator, threaded-native, or PJRT artifacts — see
//! [`crate::api::registry`]); the scheduler owns what the backends must
//! not: mask folding, energy accounting, and the survivor → class
//! priority encoding.
//!
//! §Perf: with a caller-held [`BatchScratch`] the division walk performs
//! no heap allocation — masks, match outputs and the backends' gather
//! scratch are all reused across divisions *and* batches.

use crate::api::backend::{DivisionMatches, DivisionRequest, MatchBackend};
use crate::tcam::params::DeviceParams;
use crate::util::rowmask::{reset_masks, RowMask};

use super::plan::ServingPlan;

/// Result of scheduling one batch.
#[derive(Clone, Debug)]
pub struct BatchOutcome {
    /// Which CAM bank produced this outcome (stamped from
    /// [`ServingPlan::bank`]; 0 for single-tree programs). The
    /// bank-combining coordinator fans one batch out across bank plans
    /// and attributes each outcome back through this field.
    pub bank: usize,
    /// Predicted class per lane (`None`: dead lane or no survivor).
    pub classes: Vec<Option<usize>>,
    /// Modeled energy total over real lanes (J).
    pub modeled_energy: f64,
    /// Active row-division evaluations (modeled, real lanes only).
    pub active_row_evals: u64,
    /// Column divisions actually walked (< `n_cwd` when the early-exit
    /// gate fired because every real lane's mask emptied).
    pub divisions_evaluated: usize,
    pub no_match: usize,
    pub multi_match: usize,
}

/// Reusable scratch for [`Scheduler::run_batch_with`]: the per-lane
/// enable masks and the backend's match output. Hold one per serving
/// loop and the batch walk allocates nothing after warm-up.
#[derive(Default)]
pub struct BatchScratch {
    enabled: Vec<RowMask>,
    matches: DivisionMatches,
}

/// Priority-encode the surviving rows into per-lane classes (lowest
/// row wins), counting no-match and multi-match events. Lanes past
/// `real_lanes` are padding and read out as `None`. Shared by the
/// sequential scheduler and the stage pipeline's collector, so the two
/// walks agree on the readout by construction.
pub(crate) fn read_survivors(
    plan: &ServingPlan,
    enabled: &[RowMask],
    real_lanes: usize,
) -> (Vec<Option<usize>>, usize, usize) {
    let mut classes = Vec::with_capacity(enabled.len());
    let mut no_match = 0;
    let mut multi_match = 0;
    for (lane, en) in enabled.iter().enumerate() {
        if lane >= real_lanes {
            classes.push(None);
            continue;
        }
        let mut ones = en.ones();
        match (ones.next(), ones.next()) {
            (None, _) => {
                no_match += 1;
                classes.push(None);
            }
            (Some(first), None) => classes.push(Some(plan.classes[first])),
            (Some(first), Some(_)) => {
                multi_match += 1;
                classes.push(Some(plan.classes[first]));
            }
        }
    }
    (classes, no_match, multi_match)
}

/// Scheduler over a prepared plan.
pub struct Scheduler<'a> {
    pub plan: &'a ServingPlan,
    pub params: &'a DeviceParams,
    /// Stop walking divisions once every real lane's mask is empty
    /// (default true — mirrors the hardware's precharge gating). The
    /// early-exit and full walks produce identical outcomes; the flag
    /// exists so tests can prove it.
    pub early_exit: bool,
}

impl<'a> Scheduler<'a> {
    pub fn new(plan: &'a ServingPlan, params: &'a DeviceParams) -> Scheduler<'a> {
        Scheduler {
            plan,
            params,
            early_exit: true,
        }
    }

    /// Execute one batch with fresh scratch (tests, one-shot callers).
    /// `queries[lane]` is the padded query bit-vector (length
    /// `n_cwd * S`); `real_lanes` lanes at the front are live, the rest
    /// are padding. Dead lanes cost no modeled energy (their SAs are
    /// gated like rogue rows).
    pub fn run_batch(
        &self,
        backend: &dyn MatchBackend,
        queries: &[Vec<bool>],
        real_lanes: usize,
    ) -> anyhow::Result<BatchOutcome> {
        let mut scratch = BatchScratch::default();
        self.run_batch_with(backend, queries, real_lanes, &mut scratch)
    }

    /// Execute one batch reusing caller-held scratch — the serving hot
    /// path ([`crate::coordinator::Coordinator`] holds one scratch for
    /// its whole lifetime).
    pub fn run_batch_with(
        &self,
        backend: &dyn MatchBackend,
        queries: &[Vec<bool>],
        real_lanes: usize,
        scratch: &mut BatchScratch,
    ) -> anyhow::Result<BatchOutcome> {
        let plan = self.plan;
        let s = plan.s;
        let lanes = queries.len();
        assert!(real_lanes <= lanes);
        for q in queries {
            assert_eq!(q.len(), plan.n_cwd * s, "query width mismatch");
        }

        // Per-lane packed enable masks over padded rows (rogue rows and
        // padding gated from the start).
        reset_masks(&mut scratch.enabled, lanes, plan.padded_rows);
        for m in scratch.enabled.iter_mut() {
            m.reset_prefix(plan.initially_active);
        }

        let mut energy_rows: u64 = 0;
        let mut divisions_evaluated = 0usize;

        for d in 0..plan.divisions.len() {
            // Hardware gating: when no real lane has a surviving row,
            // nothing precharges — the remaining divisions are free.
            if self.early_exit
                && scratch.enabled[..real_lanes].iter().all(|m| !m.any())
            {
                break;
            }

            // Modeled energy: active rows of real lanes pay this
            // division (a popcount per lane, not a byte scan).
            for m in scratch.enabled.iter().take(real_lanes) {
                energy_rows += m.count_ones() as u64;
            }

            // Evaluate all row tiles through the backend.
            let req = DivisionRequest {
                division: d,
                queries,
                enabled: &scratch.enabled,
            };
            backend.match_division(plan, &req, &mut scratch.matches)?;
            divisions_evaluated += 1;

            // Fold: word-wise AND of match bits into the enable masks.
            for (en, m) in scratch.enabled.iter_mut().zip(&scratch.matches) {
                en.and_assign(m);
            }
        }

        // Survivors -> classes (priority encoder: lowest row wins).
        let (classes, no_match, multi_match) =
            read_survivors(plan, &scratch.enabled, real_lanes);

        let modeled_energy =
            energy_rows as f64 * plan.e_row + real_lanes as f64 * plan.e_mem;
        Ok(BatchOutcome {
            bank: plan.bank,
            classes,
            modeled_energy,
            active_row_evals: energy_rows,
            divisions_evaluated,
            no_match,
            multi_match,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{NativeBackend, PjrtBackend, ThreadedNativeBackend};
    use crate::cart::{train, TrainParams};
    use crate::compiler::{compile, Lut};
    use crate::dataset::{catalog, Dataset};
    use crate::synth::mapping::MappedArray;
    use crate::util::prng::Prng;

    fn setup(name: &str, s: usize) -> (Dataset, Lut, MappedArray, DeviceParams) {
        let mut d = catalog::by_name(name, 0xD72CA0).unwrap();
        d.normalize();
        let (xs, ys) = (&d.features, &d.labels);
        let tree = train(xs, ys, d.n_classes, &TrainParams::default());
        let lut = compile(&tree);
        let p = DeviceParams::default();
        let mut rng = Prng::new(3);
        let m = MappedArray::from_lut(&lut, s, &p, &mut rng);
        (d, lut, m, p)
    }

    #[test]
    fn native_scheduler_matches_lut_classification() {
        let (d, lut, m, p) = setup("iris", 16);
        let plan = ServingPlan::build(&m, &m.vref, &p);
        let sched = Scheduler::new(&plan, &p);
        let backend = NativeBackend::new();

        let queries: Vec<Vec<bool>> = d.features[..32]
            .iter()
            .map(|x| m.pad_query(&lut.encode_input(x)))
            .collect();
        let out = sched.run_batch(&backend, &queries, 32).unwrap();
        assert_eq!(out.no_match, 0);
        assert_eq!(out.multi_match, 0);
        assert_eq!(out.divisions_evaluated, plan.n_cwd);
        for (i, x) in d.features[..32].iter().enumerate() {
            assert_eq!(out.classes[i], lut.classify(x), "lane {i}");
        }
        assert!(out.modeled_energy > 0.0);
    }

    #[test]
    fn dead_lanes_cost_nothing_and_return_none() {
        let (d, lut, m, p) = setup("iris", 16);
        let plan = ServingPlan::build(&m, &m.vref, &p);
        let sched = Scheduler::new(&plan, &p);
        let backend = NativeBackend::new();

        let mut queries: Vec<Vec<bool>> = d.features[..2]
            .iter()
            .map(|x| m.pad_query(&lut.encode_input(x)))
            .collect();
        queries.push(vec![false; m.padded_width]); // dead lane
        let out_3 = sched.run_batch(&backend, &queries, 2).unwrap();
        assert_eq!(out_3.classes[2], None);

        let out_2 = sched
            .run_batch(&backend, &queries[..2].to_vec(), 2)
            .unwrap();
        assert_eq!(out_3.modeled_energy, out_2.modeled_energy);
    }

    #[test]
    fn multi_division_sp_masks_propagate() {
        // haberman at S=16 has multiple divisions; scheduler must agree
        // with the synthesizer's functional simulation classification.
        let (d, lut, m, p) = setup("haberman", 16);
        assert!(m.n_cwd > 1);
        let plan = ServingPlan::build(&m, &m.vref, &p);
        let sched = Scheduler::new(&plan, &p);
        let backend = NativeBackend::new();

        let queries: Vec<Vec<bool>> = d.features[..16]
            .iter()
            .map(|x| m.pad_query(&lut.encode_input(x)))
            .collect();
        let out = sched.run_batch(&backend, &queries, 16).unwrap();
        for (i, x) in d.features[..16].iter().enumerate() {
            assert_eq!(out.classes[i], lut.classify(x), "lane {i}");
        }
    }

    #[test]
    fn scratch_reuse_across_batches_is_identical() {
        // The serving loop reuses one BatchScratch; outcomes must match
        // fresh-scratch runs batch after batch, including after a batch
        // of different width.
        let (d, lut, m, p) = setup("haberman", 16);
        let plan = ServingPlan::build(&m, &m.vref, &p);
        let sched = Scheduler::new(&plan, &p);
        let backend = NativeBackend::new();
        let queries: Vec<Vec<bool>> = d.features[..24]
            .iter()
            .map(|x| m.pad_query(&lut.encode_input(x)))
            .collect();

        let mut scratch = BatchScratch::default();
        for chunk in [&queries[..16], &queries[16..24], &queries[..24]] {
            let fresh = sched.run_batch(&backend, chunk, chunk.len()).unwrap();
            let reused = sched
                .run_batch_with(&backend, chunk, chunk.len(), &mut scratch)
                .unwrap();
            assert_eq!(fresh.classes, reused.classes);
            assert_eq!(fresh.active_row_evals, reused.active_row_evals);
            assert_eq!(fresh.modeled_energy, reused.modeled_energy);
        }
    }

    #[test]
    fn early_exit_matches_full_walk_and_skips_dead_divisions() {
        // Force division 0 to kill every row (thresholds at -inf: no
        // conductance sum can be below them), then prove the early-exit
        // walk reports identical classes/energy to the full walk while
        // evaluating only the first division.
        let (d, lut, m, p) = setup("haberman", 16);
        let mut plan = ServingPlan::build(&m, &m.vref, &p);
        assert!(plan.n_cwd > 1);
        for t in plan.divisions[0].gthresh.iter_mut() {
            *t = f32::NEG_INFINITY;
        }
        let backend = NativeBackend::new();
        let queries: Vec<Vec<bool>> = d.features[..8]
            .iter()
            .map(|x| m.pad_query(&lut.encode_input(x)))
            .collect();

        let mut gated = Scheduler::new(&plan, &p);
        gated.early_exit = true;
        let mut full = Scheduler::new(&plan, &p);
        full.early_exit = false;

        let a = gated.run_batch(&backend, &queries, 8).unwrap();
        let b = full.run_batch(&backend, &queries, 8).unwrap();
        assert_eq!(a.divisions_evaluated, 1, "gate must fire after div 0");
        assert_eq!(b.divisions_evaluated, plan.n_cwd);
        assert_eq!(a.no_match, 8);
        assert_eq!(a.classes, b.classes);
        assert_eq!(a.modeled_energy, b.modeled_energy);
        assert_eq!(a.active_row_evals, b.active_row_evals);
        assert_eq!(a.no_match, b.no_match);
        assert_eq!(a.multi_match, b.multi_match);
    }

    #[test]
    fn threaded_native_scheduler_agrees_with_native() {
        let (d, lut, m, p) = setup("haberman", 16);
        let plan = ServingPlan::build(&m, &m.vref, &p);
        let sched = Scheduler::new(&plan, &p);

        let queries: Vec<Vec<bool>> = d.features[..24]
            .iter()
            .map(|x| m.pad_query(&lut.encode_input(x)))
            .collect();
        let native = sched
            .run_batch(&NativeBackend::new(), &queries, 24)
            .unwrap();
        let threaded = sched
            .run_batch(&ThreadedNativeBackend::new(4), &queries, 24)
            .unwrap();
        assert_eq!(native.classes, threaded.classes);
        assert_eq!(native.modeled_energy, threaded.modeled_energy);
        assert_eq!(native.active_row_evals, threaded.active_row_evals);
    }

    #[test]
    fn pjrt_and_native_schedulers_agree() {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let pjrt = PjrtBackend::from_dir(&dir).unwrap();
        let (d, lut, m, p) = setup("haberman", 16);
        let plan = ServingPlan::build(&m, &m.vref, &p);
        let sched = Scheduler::new(&plan, &p);

        let queries: Vec<Vec<bool>> = d.features[..32]
            .iter()
            .map(|x| m.pad_query(&lut.encode_input(x)))
            .collect();
        let native = sched
            .run_batch(&NativeBackend::new(), &queries, 32)
            .unwrap();
        let got = sched.run_batch(&pjrt, &queries, 32).unwrap();
        assert_eq!(native.classes, got.classes);
        assert_eq!(native.modeled_energy, got.modeled_energy);
    }
}
