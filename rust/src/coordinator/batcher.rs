//! Dynamic batcher: groups incoming requests into fixed-width batches.
//!
//! The TCAM searches all rows in one shot regardless of how many lanes
//! carry real queries, so the artifact batch width B is a *hardware*
//! quantity; the batcher's job is classic serving-systems work — fill
//! lanes quickly, never hold a request past its deadline, pad partial
//! batches with dead lanes.
//!
//! Since the server became multi-program (hot swap, pinned tenants),
//! pending batches are **keyed by `(program id, version)`**: a batch
//! dispatches against exactly one program's banks, so two programs'
//! rows must never coalesce into one hardware batch — not even across
//! the instant an activation lands between a `submit` and the next
//! `take_due`. Requests stay FIFO *within* a key, which is what keeps
//! per-tenant classes and modeled energy bit-identical to
//! single-program serving.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// One inference request.
#[derive(Clone, Debug)]
pub struct InferenceRequest {
    pub id: u64,
    pub features: Vec<f64>,
    pub arrived: Instant,
    /// Trace id assigned at admission when this request was sampled for
    /// tracing; 0 = untraced (the common case — span recording is a
    /// single branch then).
    pub trace: u64,
    /// Tenant pin: `Some(id)` routes to that resident program
    /// regardless of which id is active; `None` follows the active id
    /// at admission. Carried on the request so the wire reader can
    /// stamp it without widening the scheduler channel's message shape.
    pub program: Option<String>,
}

impl InferenceRequest {
    pub fn new(id: u64, features: Vec<f64>) -> InferenceRequest {
        InferenceRequest {
            id,
            features,
            arrived: Instant::now(),
            trace: 0,
            program: None,
        }
    }

    /// Same, carrying a sampled trace id (`dt2cam serve --trace-sample`).
    pub fn traced(id: u64, features: Vec<f64>, trace: u64) -> InferenceRequest {
        InferenceRequest {
            trace,
            ..InferenceRequest::new(id, features)
        }
    }

    /// Pin this request to a program id (builder-style; `None` clears).
    pub fn with_program(mut self, program: Option<String>) -> InferenceRequest {
        self.program = program;
        self
    }
}

/// The identity a pending batch dispatches against: which program, and
/// which loaded version of it. Stamped at admission — an activation or
/// reload between admission and dispatch changes *future* keys, never
/// a stamped one.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct BatchKey {
    pub program: String,
    pub version: u64,
}

impl BatchKey {
    pub fn new(program: &str, version: u64) -> BatchKey {
        BatchKey {
            program: program.to_string(),
            version,
        }
    }
}

/// Deadline-driven fixed-width batcher, keyed by program version.
#[derive(Debug)]
pub struct Batcher {
    /// One FIFO queue per batch key, in key-arrival order. Emptied
    /// queues are dropped so stale `(id, version)` keys from old swaps
    /// cannot accumulate.
    queues: Vec<(BatchKey, VecDeque<InferenceRequest>)>,
    batch_width: usize,
    max_wait: Duration,
}

impl Batcher {
    pub fn new(batch_width: usize, max_wait: Duration) -> Batcher {
        assert!(batch_width >= 1);
        Batcher {
            queues: Vec::new(),
            batch_width,
            max_wait,
        }
    }

    pub fn push(&mut self, key: BatchKey, req: InferenceRequest) {
        match self.queues.iter_mut().find(|(k, _)| *k == key) {
            Some((_, q)) => q.push_back(req),
            None => {
                let mut q = VecDeque::new();
                q.push_back(req);
                self.queues.push((key, q));
            }
        }
    }

    pub fn pending(&self) -> usize {
        self.queues.iter().map(|(_, q)| q.len()).sum()
    }

    pub fn batch_width(&self) -> usize {
        self.batch_width
    }

    pub fn max_wait(&self) -> Duration {
        self.max_wait
    }

    /// Retune the partial-batch deadline. The socket server uses this to
    /// trade tail latency for coalescing; already-queued requests are
    /// judged against the new deadline on the next [`Batcher::next_batch`].
    pub fn set_max_wait(&mut self, max_wait: Duration) {
        self.max_wait = max_wait;
    }

    /// Take the next batch if one is ready: the first key (in arrival
    /// order) holding either a full batch or a partial one whose oldest
    /// request has waited past `max_wait`. The batch never mixes keys.
    pub fn next_batch(&mut self, now: Instant) -> Option<(BatchKey, Vec<InferenceRequest>)> {
        let idx = self.queues.iter().position(|(_, q)| {
            q.len() >= self.batch_width
                || q.front()
                    .is_some_and(|r| now.duration_since(r.arrived) >= self.max_wait)
        })?;
        let n = self.queues[idx].1.len().min(self.batch_width);
        let batch: Vec<InferenceRequest> = self.queues[idx].1.drain(..n).collect();
        let key = self.queues[idx].0.clone();
        if self.queues[idx].1.is_empty() {
            self.queues.remove(idx);
        }
        Some((key, batch))
    }

    /// Drain every batch due at `now` (full batches and overdue
    /// partials); with `force` also flush the remainder. The one call
    /// site both coordinator execution modes (sequential and pipelined)
    /// share, so their release policy cannot drift.
    pub fn take_due(&mut self, now: Instant, force: bool) -> Vec<(BatchKey, Vec<InferenceRequest>)> {
        let mut out = Vec::new();
        while let Some(b) = self.next_batch(now) {
            out.push(b);
        }
        if force {
            out.extend(self.flush());
        }
        out
    }

    /// Drain everything into batches (end-of-stream flush), per key in
    /// key-arrival order.
    pub fn flush(&mut self) -> Vec<(BatchKey, Vec<InferenceRequest>)> {
        let mut out = Vec::new();
        for (key, mut q) in self.queues.drain(..) {
            while !q.is_empty() {
                let n = q.len().min(self.batch_width);
                out.push((key.clone(), q.drain(..n).collect()));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> InferenceRequest {
        InferenceRequest::new(id, vec![0.0])
    }

    fn key() -> BatchKey {
        BatchKey::new("default", 1)
    }

    #[test]
    fn full_batch_releases_immediately() {
        let mut b = Batcher::new(4, Duration::from_secs(10));
        for i in 0..4 {
            b.push(key(), req(i));
        }
        let (k, batch) = b.next_batch(Instant::now()).unwrap();
        assert_eq!(k, key());
        assert_eq!(batch.len(), 4);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn partial_batch_waits_for_deadline() {
        let mut b = Batcher::new(4, Duration::from_millis(50));
        b.push(key(), req(0));
        assert!(b.next_batch(Instant::now()).is_none());
        let later = Instant::now() + Duration::from_millis(60);
        let (_, batch) = b.next_batch(later).unwrap();
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn deadline_boundary_is_inclusive_with_no_new_pushes() {
        // An overdue partial batch must release on a bare poll — no
        // intervening push — and the >= comparison makes the deadline
        // instant itself sufficient.
        let mut b = Batcher::new(4, Duration::from_millis(50));
        let r = req(0);
        let boundary = r.arrived + Duration::from_millis(50);
        b.push(key(), r);
        assert!(b.next_batch(boundary - Duration::from_millis(1)).is_none());
        let (_, batch) = b.next_batch(boundary).unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn empty_queue_yields_nothing() {
        let mut b = Batcher::new(4, Duration::from_millis(1));
        assert!(b.next_batch(Instant::now()).is_none());
        assert!(b.flush().is_empty());
    }

    #[test]
    fn oversize_queue_yields_width_sized_batches() {
        let mut b = Batcher::new(3, Duration::from_secs(1));
        for i in 0..7 {
            b.push(key(), req(i));
        }
        assert_eq!(b.next_batch(Instant::now()).unwrap().1.len(), 3);
        assert_eq!(b.next_batch(Instant::now()).unwrap().1.len(), 3);
        assert!(b.next_batch(Instant::now()).is_none()); // 1 left, not due
        let flushed = b.flush();
        assert_eq!(flushed.len(), 1);
        assert_eq!(flushed[0].1.len(), 1);
    }

    #[test]
    fn max_wait_can_be_retuned_live() {
        let mut b = Batcher::new(4, Duration::from_secs(3600));
        b.push(key(), req(0));
        assert!(b.next_batch(Instant::now()).is_none());
        b.set_max_wait(Duration::ZERO);
        assert_eq!(b.max_wait(), Duration::ZERO);
        // The queued request is judged against the new deadline.
        assert_eq!(b.next_batch(Instant::now()).unwrap().1.len(), 1);
    }

    #[test]
    fn take_due_releases_full_batches_and_flushes_on_force() {
        let mut b = Batcher::new(3, Duration::from_secs(3600));
        for i in 0..7 {
            b.push(key(), req(i));
        }
        // Two full batches release; the partial is held (deadline far).
        let due = b.take_due(Instant::now(), false);
        assert_eq!(due.iter().map(|(_, v)| v.len()).collect::<Vec<_>>(), vec![3, 3]);
        assert_eq!(b.pending(), 1);
        // Force drains the remainder.
        let forced = b.take_due(Instant::now(), true);
        assert_eq!(forced.len(), 1);
        assert_eq!(forced[0].1.len(), 1);
        assert_eq!(forced[0].1[0].id, 6);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn fifo_order_preserved() {
        let mut b = Batcher::new(2, Duration::from_secs(1));
        for i in 0..4 {
            b.push(key(), req(i));
        }
        let ids: Vec<u64> = b
            .next_batch(Instant::now())
            .unwrap()
            .1
            .iter()
            .map(|r| r.id)
            .collect();
        assert_eq!(ids, vec![0, 1]);
    }

    #[test]
    fn swap_between_submit_and_take_due_never_mixes_programs() {
        // Regression for the hot-swap hazard this keying exists for: a
        // request admitted under (A, 1) is pending when an activation
        // lands and the next request is stamped (B, 2). A deadline-only
        // batcher would coalesce both into one hardware batch; keyed,
        // each dispatches against its own program.
        let mut b = Batcher::new(4, Duration::ZERO);
        b.push(BatchKey::new("A", 1), req(0));
        b.push(BatchKey::new("A", 1), req(1));
        // …activation flips A→B between submit and take_due…
        b.push(BatchKey::new("B", 2), req(2));
        let due = b.take_due(Instant::now(), false);
        assert_eq!(due.len(), 2, "one batch per key, never one mixed batch");
        assert_eq!(due[0].0, BatchKey::new("A", 1));
        assert_eq!(due[0].1.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(due[1].0, BatchKey::new("B", 2));
        assert_eq!(due[1].1.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2]);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn reload_of_same_id_is_a_distinct_key() {
        // Same program id, bumped version (an in-place reload): still
        // two batches — the version is part of the key.
        let mut b = Batcher::new(8, Duration::ZERO);
        b.push(BatchKey::new("A", 1), req(0));
        b.push(BatchKey::new("A", 2), req(1));
        let due = b.take_due(Instant::now(), false);
        assert_eq!(due.len(), 2);
        assert_eq!(due[0].0.version, 1);
        assert_eq!(due[1].0.version, 2);
    }

    #[test]
    fn keys_release_in_arrival_order_and_fifo_within_key() {
        let mut b = Batcher::new(2, Duration::from_secs(3600));
        b.push(BatchKey::new("A", 1), req(0));
        b.push(BatchKey::new("B", 2), req(10));
        b.push(BatchKey::new("A", 1), req(1));
        b.push(BatchKey::new("B", 2), req(11));
        let due = b.take_due(Instant::now(), false);
        assert_eq!(due.len(), 2);
        assert_eq!(due[0].0, BatchKey::new("A", 1));
        assert_eq!(due[0].1.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(due[1].1.iter().map(|r| r.id).collect::<Vec<_>>(), vec![10, 11]);
    }
}
