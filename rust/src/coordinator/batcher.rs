//! Dynamic batcher: groups incoming requests into fixed-width batches.
//!
//! The TCAM searches all rows in one shot regardless of how many lanes
//! carry real queries, so the artifact batch width B is a *hardware*
//! quantity; the batcher's job is classic serving-systems work — fill
//! lanes quickly, never hold a request past its deadline, pad partial
//! batches with dead lanes.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// One inference request.
#[derive(Clone, Debug)]
pub struct InferenceRequest {
    pub id: u64,
    pub features: Vec<f64>,
    pub arrived: Instant,
    /// Trace id assigned at admission when this request was sampled for
    /// tracing; 0 = untraced (the common case — span recording is a
    /// single branch then).
    pub trace: u64,
}

impl InferenceRequest {
    pub fn new(id: u64, features: Vec<f64>) -> InferenceRequest {
        InferenceRequest {
            id,
            features,
            arrived: Instant::now(),
            trace: 0,
        }
    }

    /// Same, carrying a sampled trace id (`dt2cam serve --trace-sample`).
    pub fn traced(id: u64, features: Vec<f64>, trace: u64) -> InferenceRequest {
        InferenceRequest {
            trace,
            ..InferenceRequest::new(id, features)
        }
    }
}

/// Deadline-driven fixed-width batcher.
#[derive(Debug)]
pub struct Batcher {
    queue: VecDeque<InferenceRequest>,
    batch_width: usize,
    max_wait: Duration,
}

impl Batcher {
    pub fn new(batch_width: usize, max_wait: Duration) -> Batcher {
        assert!(batch_width >= 1);
        Batcher {
            queue: VecDeque::new(),
            batch_width,
            max_wait,
        }
    }

    pub fn push(&mut self, req: InferenceRequest) {
        self.queue.push_back(req);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    pub fn batch_width(&self) -> usize {
        self.batch_width
    }

    pub fn max_wait(&self) -> Duration {
        self.max_wait
    }

    /// Retune the partial-batch deadline. The socket server uses this to
    /// trade tail latency for coalescing; already-queued requests are
    /// judged against the new deadline on the next [`Batcher::next_batch`].
    pub fn set_max_wait(&mut self, max_wait: Duration) {
        self.max_wait = max_wait;
    }

    /// Take the next batch if one is ready: either a full batch, or a
    /// partial one whose oldest request has waited past `max_wait`.
    pub fn next_batch(&mut self, now: Instant) -> Option<Vec<InferenceRequest>> {
        if self.queue.is_empty() {
            return None;
        }
        let full = self.queue.len() >= self.batch_width;
        let overdue = now.duration_since(self.queue[0].arrived) >= self.max_wait;
        if !full && !overdue {
            return None;
        }
        let n = self.queue.len().min(self.batch_width);
        Some(self.queue.drain(..n).collect())
    }

    /// Drain every batch due at `now` (full batches and overdue
    /// partials); with `force` also flush the remainder. The one call
    /// site both coordinator execution modes (sequential and pipelined)
    /// share, so their release policy cannot drift.
    pub fn take_due(&mut self, now: Instant, force: bool) -> Vec<Vec<InferenceRequest>> {
        let mut out = Vec::new();
        while let Some(b) = self.next_batch(now) {
            out.push(b);
        }
        if force {
            out.extend(self.flush());
        }
        out
    }

    /// Drain everything into batches (end-of-stream flush).
    pub fn flush(&mut self) -> Vec<Vec<InferenceRequest>> {
        let mut out = Vec::new();
        while !self.queue.is_empty() {
            let n = self.queue.len().min(self.batch_width);
            out.push(self.queue.drain(..n).collect());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> InferenceRequest {
        InferenceRequest::new(id, vec![0.0])
    }

    #[test]
    fn full_batch_releases_immediately() {
        let mut b = Batcher::new(4, Duration::from_secs(10));
        for i in 0..4 {
            b.push(req(i));
        }
        let batch = b.next_batch(Instant::now()).unwrap();
        assert_eq!(batch.len(), 4);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn partial_batch_waits_for_deadline() {
        let mut b = Batcher::new(4, Duration::from_millis(50));
        b.push(req(0));
        assert!(b.next_batch(Instant::now()).is_none());
        let later = Instant::now() + Duration::from_millis(60);
        let batch = b.next_batch(later).unwrap();
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn deadline_boundary_is_inclusive_with_no_new_pushes() {
        // An overdue partial batch must release on a bare poll — no
        // intervening push — and the >= comparison makes the deadline
        // instant itself sufficient.
        let mut b = Batcher::new(4, Duration::from_millis(50));
        let r = req(0);
        let boundary = r.arrived + Duration::from_millis(50);
        b.push(r);
        assert!(b.next_batch(boundary - Duration::from_millis(1)).is_none());
        let batch = b.next_batch(boundary).unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn empty_queue_yields_nothing() {
        let mut b = Batcher::new(4, Duration::from_millis(1));
        assert!(b.next_batch(Instant::now()).is_none());
        assert!(b.flush().is_empty());
    }

    #[test]
    fn oversize_queue_yields_width_sized_batches() {
        let mut b = Batcher::new(3, Duration::from_secs(1));
        for i in 0..7 {
            b.push(req(i));
        }
        assert_eq!(b.next_batch(Instant::now()).unwrap().len(), 3);
        assert_eq!(b.next_batch(Instant::now()).unwrap().len(), 3);
        assert!(b.next_batch(Instant::now()).is_none()); // 1 left, not due
        let flushed = b.flush();
        assert_eq!(flushed.len(), 1);
        assert_eq!(flushed[0].len(), 1);
    }

    #[test]
    fn max_wait_can_be_retuned_live() {
        let mut b = Batcher::new(4, Duration::from_secs(3600));
        b.push(req(0));
        assert!(b.next_batch(Instant::now()).is_none());
        b.set_max_wait(Duration::ZERO);
        assert_eq!(b.max_wait(), Duration::ZERO);
        // The queued request is judged against the new deadline.
        assert_eq!(b.next_batch(Instant::now()).unwrap().len(), 1);
    }

    #[test]
    fn take_due_releases_full_batches_and_flushes_on_force() {
        let mut b = Batcher::new(3, Duration::from_secs(3600));
        for i in 0..7 {
            b.push(req(i));
        }
        // Two full batches release; the partial is held (deadline far).
        let due = b.take_due(Instant::now(), false);
        assert_eq!(due.iter().map(Vec::len).collect::<Vec<_>>(), vec![3, 3]);
        assert_eq!(b.pending(), 1);
        // Force drains the remainder.
        let forced = b.take_due(Instant::now(), true);
        assert_eq!(forced.len(), 1);
        assert_eq!(forced[0].len(), 1);
        assert_eq!(forced[0][0].id, 6);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn fifo_order_preserved() {
        let mut b = Batcher::new(2, Duration::from_secs(1));
        for i in 0..4 {
            b.push(req(i));
        }
        let ids: Vec<u64> = b
            .next_batch(Instant::now())
            .unwrap()
            .iter()
            .map(|r| r.id)
            .collect();
        assert_eq!(ids, vec![0, 1]);
    }
}
