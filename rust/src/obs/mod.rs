//! Observability plane: mergeable histograms, request tracing, export.
//!
//! Std-only, like `net/`. Three pieces:
//!
//! - [`hist`] — fixed-schema log2 histograms whose `merge` is bucket-wise
//!   addition, making cluster-wide percentiles exact to bucket
//!   resolution (this replaced the approximate decision-weighted
//!   percentile merge in `MetricsSnapshot::merge`).
//! - [`trace`] — per-request trace ids assigned at admission plus a
//!   bounded span ring covering admission → queue → dispatch →
//!   bank-match/stage → remote → vote → respond.
//! - [`export`] — Prometheus-style text exposition (served over
//!   `Frame::ObsScrape`/`ObsReport`) and Chrome trace-event JSON dumps.
//!
//! See `docs/API.md` §Observability for the span taxonomy, the bucket
//! schema, and the overhead contract.

pub mod export;
pub mod hist;
pub mod trace;

pub use export::{chrome_trace_json, parse_stage_totals, prometheus_text};
pub use hist::{bucket_index, bucket_upper, bucket_width, Histogram, N_BUCKETS};
pub use trace::{Span, SpanKind, Tracer, DEFAULT_RING_CAPACITY, NO_INDEX, SPAN_KINDS};
