//! Exposition formats: Prometheus-style text and Chrome trace-event JSON.
//!
//! Both are *derived* views — the data lives in [`MetricsSnapshot`]
//! (counters + mergeable histograms) and the [`Tracer`] span ring. The
//! text form rides the wire in `Frame::ObsReport` and is what `dt2cam
//! loadgen` parses for its per-stage breakdown; the Chrome form is what
//! `dt2cam trace --out spans.json` writes (loadable in
//! `chrome://tracing` / Perfetto).

use crate::net::protocol::MetricsSnapshot;
use crate::obs::hist::{bucket_upper, Histogram};
use crate::obs::trace::{Span, Tracer, NO_INDEX};
use crate::config::json::Json;

use std::fmt::Write as _;

fn counter(out: &mut String, name: &str, v: u64) {
    let _ = writeln!(out, "# TYPE {name} counter\n{name} {v}");
}

fn gauge(out: &mut String, name: &str, v: f64) {
    let _ = writeln!(out, "# TYPE {name} gauge\n{name} {v}");
}

fn histogram(out: &mut String, name: &str, h: &Histogram) {
    let _ = writeln!(out, "# TYPE {name} histogram");
    let mut cum = 0u64;
    for (i, &c) in h.buckets().iter().enumerate() {
        cum += c;
        if c != 0 {
            let _ = writeln!(out, "{name}_bucket{{le=\"{}\"}} {cum}", bucket_upper(i));
        }
    }
    let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {}", h.count());
    let _ = writeln!(out, "{name}_sum {}", h.sum());
    let _ = writeln!(out, "{name}_count {}", h.count());
}

/// Render a snapshot (plus optional tracer state) as Prometheus-style
/// text exposition. Stable line prefixes are a contract: `loadgen`
/// parses `dt2cam_stage_ns_total` / `dt2cam_stage_count` back out with
/// [`parse_stage_totals`].
pub fn prometheus_text(snap: &MetricsSnapshot, uptime_s: u64, tracer: Option<&Tracer>) -> String {
    let mut out = String::with_capacity(4096);
    counter(&mut out, "dt2cam_requests_total", snap.requests);
    counter(&mut out, "dt2cam_decisions_total", snap.decisions);
    counter(&mut out, "dt2cam_batches_total", snap.batches);
    counter(&mut out, "dt2cam_shed_total", snap.shed);
    counter(&mut out, "dt2cam_dropped_responses_total", snap.dropped);
    counter(&mut out, "dt2cam_protocol_errors_total", snap.protocol_errors);
    counter(&mut out, "dt2cam_no_match_total", snap.no_match);
    counter(&mut out, "dt2cam_multi_match_total", snap.multi_match);
    gauge(&mut out, "dt2cam_connections", snap.connections as f64);
    gauge(&mut out, "dt2cam_banks", snap.n_banks as f64);
    gauge(&mut out, "dt2cam_rows_total", snap.rows_total as f64);
    gauge(&mut out, "dt2cam_rows_physical", snap.rows_physical as f64);
    gauge(&mut out, "dt2cam_uptime_seconds", uptime_s as f64);
    // Snapshot latencies are seconds and energy is joules; the gauge
    // names carry the exported unit, so convert here.
    gauge(&mut out, "dt2cam_energy_per_decision_nj", snap.energy_per_dec * 1e9);
    gauge(&mut out, "dt2cam_modeled_latency_us", snap.modeled_latency * 1e6);
    gauge(&mut out, "dt2cam_wall_throughput_dps", snap.wall_throughput);
    gauge(&mut out, "dt2cam_queue_delay_mean_us", snap.queue_delay_mean * 1e6);
    for (q, v) in [
        ("0.5", snap.latency_p50 * 1e6),
        ("0.95", snap.latency_p95 * 1e6),
        ("0.99", snap.latency_p99 * 1e6),
    ] {
        let _ = writeln!(out, "dt2cam_latency_us{{quantile=\"{q}\"}} {v}");
    }
    histogram(&mut out, "dt2cam_latency_ns", &snap.latency_hist);
    histogram(&mut out, "dt2cam_queue_delay_ns", &snap.queue_hist);
    histogram(&mut out, "dt2cam_batch_size", &snap.batch_hist);
    if let Some(t) = tracer {
        gauge(&mut out, "dt2cam_trace_sample", t.sample() as f64);
        counter(&mut out, "dt2cam_trace_spans_dropped_total", t.dropped());
        let _ = writeln!(out, "# TYPE dt2cam_stage_ns_total counter");
        let _ = writeln!(out, "# TYPE dt2cam_stage_count counter");
        for (name, ns, count) in t.stage_totals() {
            let _ = writeln!(out, "dt2cam_stage_ns_total{{stage=\"{name}\"}} {ns}");
            let _ = writeln!(out, "dt2cam_stage_count{{stage=\"{name}\"}} {count}");
        }
    }
    out
}

/// Parse `dt2cam_stage_ns_total`/`dt2cam_stage_count` rows back out of
/// an exposition text: `(stage, total_ns, count)`, in taxonomy order of
/// appearance. Tolerant of everything else in the text.
pub fn parse_stage_totals(text: &str) -> Vec<(String, u64, u64)> {
    fn labeled(line: &str, prefix: &str) -> Option<(String, u64)> {
        let rest = line.strip_prefix(prefix)?.strip_prefix("{stage=\"")?;
        let (stage, rest) = rest.split_once("\"}")?;
        let v = rest.trim().parse::<u64>().ok()?;
        Some((stage.to_string(), v))
    }
    let mut order: Vec<String> = Vec::new();
    let mut ns: Vec<(String, u64)> = Vec::new();
    let mut counts: Vec<(String, u64)> = Vec::new();
    for line in text.lines() {
        if let Some((stage, v)) = labeled(line, "dt2cam_stage_ns_total") {
            if !order.contains(&stage) {
                order.push(stage.clone());
            }
            ns.push((stage, v));
        } else if let Some((stage, v)) = labeled(line, "dt2cam_stage_count") {
            counts.push((stage, v));
        }
    }
    order
        .into_iter()
        .map(|stage| {
            let total = ns.iter().find(|(s, _)| *s == stage).map(|&(_, v)| v).unwrap_or(0);
            let n = counts.iter().find(|(s, _)| *s == stage).map(|&(_, v)| v).unwrap_or(0);
            (stage, total, n)
        })
        .collect()
}

/// Render spans as Chrome trace-event JSON (the `{"traceEvents": [...]}`
/// object form). Complete events (`ph: "X"`), timestamps in
/// microseconds, one `tid` per trace id so each request gets its own
/// row in the viewer.
pub fn chrome_trace_json(spans: &[Span]) -> String {
    let events: Vec<Json> = spans
        .iter()
        .map(|s| {
            let mut args = vec![("trace", Json::num(s.trace as f64))];
            if s.bank != NO_INDEX {
                args.push(("bank", Json::num(s.bank as f64)));
            }
            if s.division != NO_INDEX {
                args.push(("division", Json::num(s.division as f64)));
            }
            let name = if s.kind == crate::obs::trace::SpanKind::Stage && s.division != NO_INDEX {
                format!("stage d{}", s.division)
            } else {
                s.kind.as_str().to_string()
            };
            Json::obj(vec![
                ("name", Json::str(name)),
                ("cat", Json::str("dt2cam")),
                ("ph", Json::str("X")),
                ("ts", Json::num(s.start_ns as f64 / 1000.0)),
                ("dur", Json::num((s.dur_ns.max(1)) as f64 / 1000.0)),
                ("pid", Json::num(1.0)),
                ("tid", Json::num(s.trace as f64)),
                ("args", Json::obj(args)),
            ])
        })
        .collect();
    Json::obj(vec![("traceEvents", Json::Arr(events))]).to_string_compact()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::SpanKind;

    fn snap_with_hist() -> MetricsSnapshot {
        let mut s = MetricsSnapshot::default();
        s.requests = 10;
        s.decisions = 10;
        s.shed = 1;
        s.dropped = 2;
        for v in [100u64, 2000, 30_000] {
            s.latency_hist.record(v);
        }
        s.batch_hist.record(8);
        s
    }

    #[test]
    fn exposition_has_counters_histograms_and_stage_rows() {
        let t = Tracer::new(1);
        t.record(1, SpanKind::Queue, None, None, 0, 500);
        t.record(1, SpanKind::Vote, None, None, 500, 20);
        let text = prometheus_text(&snap_with_hist(), 12, Some(&t));
        assert!(text.contains("dt2cam_requests_total 10"));
        assert!(text.contains("dt2cam_dropped_responses_total 2"));
        assert!(text.contains("dt2cam_uptime_seconds 12"));
        assert!(text.contains("dt2cam_latency_ns_count 3"));
        assert!(text.contains("dt2cam_latency_ns_bucket{le=\"+Inf\"} 3"));
        assert!(text.contains("dt2cam_batch_size_count 1"));
        assert!(text.contains("dt2cam_stage_ns_total{stage=\"queue\"} 500"));
        assert!(text.contains("dt2cam_stage_count{stage=\"vote\"} 1"));

        let rows = parse_stage_totals(&text);
        let queue = rows.iter().find(|(s, _, _)| s == "queue").unwrap();
        assert_eq!((queue.1, queue.2), (500, 1));
        let vote = rows.iter().find(|(s, _, _)| s == "vote").unwrap();
        assert_eq!((vote.1, vote.2), (20, 1));
    }

    #[test]
    fn exposition_without_tracer_omits_stage_rows() {
        let text = prometheus_text(&snap_with_hist(), 0, None);
        assert!(!text.contains("dt2cam_stage_ns_total"));
        assert!(parse_stage_totals(&text).is_empty());
    }

    #[test]
    fn chrome_trace_is_valid_json_with_complete_events() {
        let spans = vec![
            Span {
                trace: 3,
                kind: SpanKind::Admission,
                bank: NO_INDEX,
                division: NO_INDEX,
                start_ns: 1000,
                dur_ns: 0,
            },
            Span {
                trace: 3,
                kind: SpanKind::Stage,
                bank: 0,
                division: 2,
                start_ns: 2000,
                dur_ns: 1500,
            },
        ];
        let text = chrome_trace_json(&spans);
        let j = Json::parse(&text).unwrap();
        let events = j.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(events[0].get("name").unwrap().as_str(), Some("admission"));
        // Zero-duration spans get a 1 ns floor so viewers render them.
        assert!(events[0].get("dur").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(events[1].get("name").unwrap().as_str(), Some("stage d2"));
        assert_eq!(
            events[1].get("args").unwrap().get("bank").unwrap().as_usize(),
            Some(0)
        );
    }
}
