//! Log2-bucketed, exactly-mergeable histograms.
//!
//! The bucket schema is *fixed* (part of the wire contract, see
//! `docs/API.md` §Observability): bucket 0 holds the value 0, bucket
//! `i >= 1` holds values in `[2^(i-1), 2^i)`, and the last bucket is
//! open-ended. Because the schema never varies, `merge` is a plain
//! bucket-wise add, so percentiles computed from a merged histogram are
//! bit-identical to percentiles computed from one histogram fed the
//! pooled samples — the property the cluster router relies on for its
//! tail-latency roll-ups (the old decision-weighted percentile merge
//! was approximate and is gone).
//!
//! Values are dimensionless `u64`s; latency histograms record
//! nanoseconds, size histograms record counts. Percentile estimates
//! return the *inclusive upper edge* of the bucket containing the rank,
//! so the estimate is within one bucket width of the true sample.

use crate::api::serde::{get_u64, json_u64};
use crate::config::json::Json;
use anyhow::{Context, Result};

/// Number of buckets. Bucket 39 starts at 2^38 ns ≈ 275 s — far above
/// any latency this system can produce, so the open tail never matters
/// in practice.
pub const N_BUCKETS: usize = 40;

/// A fixed-schema log2 histogram. `merge` is bucket-wise addition and
/// therefore exact: order and grouping of merges never change any
/// derived statistic.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Histogram {
    counts: [u64; N_BUCKETS],
    count: u64,
    sum: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            counts: [0; N_BUCKETS],
            count: 0,
            sum: 0,
        }
    }
}

/// Bucket index for a value: 0 for 0, else `floor(log2(v)) + 1`,
/// clamped into the open-ended last bucket.
pub fn bucket_index(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        ((64 - v.leading_zeros()) as usize).min(N_BUCKETS - 1)
    }
}

/// Inclusive upper edge of a bucket (`2^i - 1`; 0 for bucket 0). The
/// open-ended last bucket reports its lower edge region's top the same
/// way — an intentional saturation, not a real bound.
pub fn bucket_upper(i: usize) -> u64 {
    if i == 0 {
        0
    } else {
        (1u64 << i.min(63)) - 1
    }
}

/// Width of a bucket: the number of distinct values it can hold.
pub fn bucket_width(i: usize) -> u64 {
    if i == 0 {
        1
    } else {
        1u64 << (i - 1).min(62)
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, v: u64) {
        self.counts[bucket_index(v)] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> u64 {
        self.sum
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Bucket-wise add — the exact merge. Associative and commutative,
    /// so sharded recording then merging gives the same histogram as
    /// centralized recording.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
    }

    /// Percentile estimate at bucket resolution: the inclusive upper
    /// edge of the bucket containing the rank-`ceil(p/100 * count)`
    /// sample. Depends only on bucket counts, so it is merge-invariant.
    pub fn percentile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((p / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let rank = rank.min(self.count);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return bucket_upper(i);
            }
        }
        bucket_upper(N_BUCKETS - 1)
    }

    /// Raw bucket counts (fixed schema, `N_BUCKETS` entries).
    pub fn buckets(&self) -> &[u64; N_BUCKETS] {
        &self.counts
    }

    /// Cumulative counts per bucket — the shape Prometheus exposition
    /// wants (`le` buckets are cumulative).
    pub fn cumulative(&self) -> Vec<u64> {
        let mut out = Vec::with_capacity(N_BUCKETS);
        let mut cum = 0u64;
        for &c in &self.counts {
            cum += c;
            out.push(cum);
        }
        out
    }

    /// Compact JSON: counts trimmed of trailing zero buckets, plus the
    /// redundant-but-cheap `count`/`sum` roll-ups. An empty histogram
    /// encodes as `{"counts":[],"count":0,"sum":0}`.
    pub fn to_json(&self) -> Json {
        let last = self
            .counts
            .iter()
            .rposition(|&c| c != 0)
            .map(|i| i + 1)
            .unwrap_or(0);
        Json::obj(vec![
            (
                "counts",
                Json::Arr(self.counts[..last].iter().map(|&c| json_u64(c)).collect()),
            ),
            ("count", json_u64(self.count)),
            ("sum", json_u64(self.sum)),
        ])
    }

    /// Decode; tolerates short count arrays (trailing zeros trimmed)
    /// and rejects arrays longer than the fixed schema.
    pub fn from_json(j: &Json) -> Result<Histogram> {
        let arr = j
            .get("counts")
            .and_then(|v| v.as_arr())
            .context("histogram needs a 'counts' array")?;
        if arr.len() > N_BUCKETS {
            anyhow::bail!(
                "histogram has {} buckets but the schema is fixed at {N_BUCKETS}",
                arr.len()
            );
        }
        let mut h = Histogram::new();
        for (i, v) in arr.iter().enumerate() {
            let wrapped = Json::obj(vec![("c", v.clone())]);
            h.counts[i] = get_u64(&wrapped, "c").context("histogram bucket count")?;
        }
        h.count = get_u64(j, "count")?;
        h.sum = get_u64(j, "sum")?;
        let bucket_total: u64 = h.counts.iter().sum();
        if bucket_total != h.count {
            anyhow::bail!(
                "histogram bucket counts sum to {bucket_total} but count says {}",
                h.count
            );
        }
        Ok(h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    #[test]
    fn bucket_schema_boundaries() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        assert_eq!(bucket_index(u64::MAX), N_BUCKETS - 1);
        // Bucket i >= 1 covers [2^(i-1), 2^i).
        for i in 1..20 {
            assert_eq!(bucket_index(1u64 << (i - 1)), i);
            assert_eq!(bucket_index((1u64 << i) - 1), i);
        }
        assert_eq!(bucket_upper(0), 0);
        assert_eq!(bucket_upper(3), 7);
        assert_eq!(bucket_width(0), 1);
        assert_eq!(bucket_width(3), 4);
    }

    #[test]
    fn record_count_sum_mean() {
        let mut h = Histogram::new();
        for v in [0, 1, 5, 100, 100] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.sum(), 206);
        assert!((h.mean() - 41.2).abs() < 1e-9);
    }

    #[test]
    fn percentile_is_within_one_bucket_of_true_sample() {
        let mut h = Histogram::new();
        let mut samples: Vec<u64> = Vec::new();
        let mut rng = Prng::new(7);
        for _ in 0..5000 {
            let v = rng.next_u64() % 1_000_000;
            h.record(v);
            samples.push(v);
        }
        samples.sort_unstable();
        for p in [50.0, 95.0, 99.0] {
            let rank = ((p / 100.0) * samples.len() as f64).ceil().max(1.0) as usize;
            let truth = samples[rank - 1];
            let est = h.percentile(p);
            assert_eq!(bucket_index(truth), bucket_index(est));
            let width = bucket_width(bucket_index(est));
            assert!(est.abs_diff(truth) < width, "p{p}: est {est} truth {truth}");
        }
    }

    #[test]
    fn merge_over_k_shards_is_bit_identical_to_pooled() {
        // The tentpole property: K sharded histograms merged in any
        // grouping report exactly the same percentiles as one histogram
        // fed the pooled samples.
        let mut rng = Prng::new(42);
        for k in [2usize, 3, 7] {
            let mut shards = vec![Histogram::new(); k];
            let mut pooled = Histogram::new();
            for i in 0..4096 {
                // Mix of scales so several buckets are populated.
                let v = match i % 3 {
                    0 => rng.next_u64() % 64,
                    1 => rng.next_u64() % 65_536,
                    _ => rng.next_u64() % 100_000_000,
                };
                shards[i % k].record(v);
                pooled.record(v);
            }
            let mut merged = Histogram::new();
            for s in &shards {
                merged.merge(s);
            }
            assert_eq!(merged, pooled);
            // Also merge in reverse order — associativity/commutativity.
            let mut rev = Histogram::new();
            for s in shards.iter().rev() {
                rev.merge(s);
            }
            assert_eq!(rev, pooled);
            for p in [1.0, 25.0, 50.0, 90.0, 95.0, 99.0, 99.9, 100.0] {
                assert_eq!(merged.percentile(p), pooled.percentile(p));
            }
            assert_eq!(merged.mean(), pooled.mean());
        }
    }

    #[test]
    fn json_roundtrip_and_empty() {
        let mut h = Histogram::new();
        for v in [0u64, 3, 3, 900, 1 << 30] {
            h.record(v);
        }
        let text = h.to_json().to_string_compact();
        let back = Histogram::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, h);

        let empty = Histogram::new();
        let back = Histogram::from_json(&Json::parse(&empty.to_json().to_string_compact()).unwrap())
            .unwrap();
        assert_eq!(back, empty);
        assert_eq!(back.percentile(99.0), 0);
    }

    #[test]
    fn json_rejects_inconsistent_or_oversized() {
        let j = Json::parse(r#"{"counts":[1,1],"count":3,"sum":0}"#).unwrap();
        assert!(Histogram::from_json(&j).is_err());
        let too_many: Vec<String> = (0..N_BUCKETS + 1).map(|_| "0".to_string()).collect();
        let j = Json::parse(&format!(
            r#"{{"counts":[{}],"count":0,"sum":0}}"#,
            too_many.join(",")
        ))
        .unwrap();
        assert!(Histogram::from_json(&j).is_err());
    }
}
