//! Per-request tracing: trace ids, typed spans, and a bounded span ring.
//!
//! A [`Tracer`] is a cheaply-cloneable handle shared by the net reader
//! threads (admission), the scheduler thread (queue/dispatch/vote), the
//! pipeline stage threads (per-division stages), and the remote
//! dispatcher (worker round-trips). Recording takes one short `Mutex`
//! lock on a fixed-capacity ring — never an allocation — and when the
//! request is unsampled (`trace == 0`) recording is a single branch, so
//! `--trace-sample 0` costs nothing on the hot path.
//!
//! Timestamps are nanoseconds since the tracer's epoch (a monotonic
//! `Instant` captured at construction); wall-clock never enters the
//! span stream, so spans from one process are internally consistent
//! even across clock steps.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::api::serde::{get_str, get_u64, json_u64};
use crate::config::json::Json;
use anyhow::{Context, Result};

/// The span taxonomy — one kind per stage of the request lifecycle.
/// The wire names (see [`SpanKind::as_str`]) are a documented contract
/// (`docs/API.md` §Observability).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    /// Frame decode + admission decision in the net reader thread.
    Admission,
    /// Time spent queued in the batcher (arrival → dispatch).
    Queue,
    /// Batch dispatch: the scheduler handing a formed batch to the
    /// execution path (whole-batch run for the sequential coordinator,
    /// pipeline feed for the streaming one).
    Dispatch,
    /// One bank's match phase over a batch (sequential and worker-side
    /// execution).
    BankMatch,
    /// One column-division stage of the streaming pipeline.
    Stage,
    /// A remote worker round-trip (router side: send `BankBatch`, wait
    /// for `BankOutcomes`).
    Remote,
    /// Survivor-vote readout across banks.
    Vote,
    /// Writing the response frame back to the client connection.
    Respond,
}

pub const SPAN_KINDS: [SpanKind; 8] = [
    SpanKind::Admission,
    SpanKind::Queue,
    SpanKind::Dispatch,
    SpanKind::BankMatch,
    SpanKind::Stage,
    SpanKind::Remote,
    SpanKind::Vote,
    SpanKind::Respond,
];

impl SpanKind {
    pub fn as_str(self) -> &'static str {
        match self {
            SpanKind::Admission => "admission",
            SpanKind::Queue => "queue",
            SpanKind::Dispatch => "dispatch",
            SpanKind::BankMatch => "bank_match",
            SpanKind::Stage => "stage",
            SpanKind::Remote => "remote",
            SpanKind::Vote => "vote",
            SpanKind::Respond => "respond",
        }
    }

    pub fn parse(s: &str) -> Option<SpanKind> {
        SPAN_KINDS.iter().copied().find(|k| k.as_str() == s)
    }

    fn index(self) -> usize {
        SPAN_KINDS.iter().position(|&k| k == self).unwrap()
    }
}

/// Sentinel for "no bank"/"no division" on spans where the dimension
/// does not apply.
pub const NO_INDEX: u32 = u32::MAX;

/// One recorded span. `bank`/`division` are [`NO_INDEX`] when not
/// applicable; timestamps are ns since the recording tracer's epoch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Span {
    pub trace: u64,
    pub kind: SpanKind,
    pub bank: u32,
    pub division: u32,
    pub start_ns: u64,
    pub dur_ns: u64,
}

impl Span {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("trace", json_u64(self.trace)),
            ("kind", Json::str(self.kind.as_str())),
        ];
        if self.bank != NO_INDEX {
            fields.push(("bank", Json::num(self.bank as f64)));
        }
        if self.division != NO_INDEX {
            fields.push(("division", Json::num(self.division as f64)));
        }
        fields.push(("start_ns", json_u64(self.start_ns)));
        fields.push(("dur_ns", json_u64(self.dur_ns)));
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<Span> {
        let kind_name = get_str(j, "kind")?;
        let kind = SpanKind::parse(&kind_name)
            .with_context(|| format!("unknown span kind '{kind_name}'"))?;
        let opt_index = |key: &str| -> Result<u32> {
            match j.get(key) {
                None | Some(Json::Null) => Ok(NO_INDEX),
                Some(v) => Ok(v
                    .as_usize()
                    .with_context(|| format!("span '{key}' must be a non-negative integer"))?
                    as u32),
            }
        };
        Ok(Span {
            trace: get_u64(j, "trace")?,
            kind,
            bank: opt_index("bank")?,
            division: opt_index("division")?,
            start_ns: get_u64(j, "start_ns")?,
            dur_ns: get_u64(j, "dur_ns")?,
        })
    }
}

/// Default span-ring capacity. At ~48 B/span this is well under 1 MiB
/// resident, and more than a scrape can ship in one frame anyway.
pub const DEFAULT_RING_CAPACITY: usize = 16384;

struct Ring {
    spans: Vec<Span>,
    /// The requested ring bound. `Vec::with_capacity` only promises
    /// *at least* that much, so the wrap/full checks use this field —
    /// never `Vec::capacity()` — to keep the bound exact.
    cap: usize,
    next: usize,
    wrapped: bool,
}

/// Per-[`SpanKind`] running totals, updated on every recorded span.
/// These feed the `dt2cam_stage_ns_total` / `dt2cam_stage_count`
/// exposition rows that `loadgen` turns into a per-stage breakdown.
struct StageTotals {
    ns: [AtomicU64; SPAN_KINDS.len()],
    count: [AtomicU64; SPAN_KINDS.len()],
}

struct Inner {
    sample: u64,
    epoch: Instant,
    next_trace: AtomicU64,
    ring: Mutex<Ring>,
    totals: StageTotals,
    dropped: AtomicU64,
}

/// Shared tracing handle. Clone freely — all clones share one ring,
/// one epoch, and one trace-id counter.
#[derive(Clone)]
pub struct Tracer {
    inner: Arc<Inner>,
}

impl Tracer {
    /// `sample` is the sampling divisor: 0 disables tracing entirely,
    /// N traces every Nth admitted request.
    pub fn new(sample: u64) -> Tracer {
        Tracer::with_capacity(sample, DEFAULT_RING_CAPACITY)
    }

    pub fn with_capacity(sample: u64, capacity: usize) -> Tracer {
        let capacity = capacity.max(1);
        Tracer {
            inner: Arc::new(Inner {
                sample,
                epoch: Instant::now(),
                next_trace: AtomicU64::new(1),
                ring: Mutex::new(Ring {
                    spans: Vec::with_capacity(capacity),
                    cap: capacity,
                    next: 0,
                    wrapped: false,
                }),
                totals: StageTotals {
                    ns: std::array::from_fn(|_| AtomicU64::new(0)),
                    count: std::array::from_fn(|_| AtomicU64::new(0)),
                },
                dropped: AtomicU64::new(0),
            }),
        }
    }

    pub fn sample(&self) -> u64 {
        self.inner.sample
    }

    pub fn enabled(&self) -> bool {
        self.inner.sample > 0
    }

    /// Admission-time trace-id assignment: every admitted request gets
    /// the next id, and the sampled ones (id divisible by the sampling
    /// divisor) return it; the rest return 0 ("untraced") so every
    /// downstream record call is a single branch.
    pub fn admit(&self) -> u64 {
        if self.inner.sample == 0 {
            return 0;
        }
        let id = self.inner.next_trace.fetch_add(1, Ordering::Relaxed);
        if id % self.inner.sample == 0 {
            id
        } else {
            0
        }
    }

    /// Nanoseconds since this tracer's epoch.
    pub fn now_ns(&self) -> u64 {
        self.inner.epoch.elapsed().as_nanos() as u64
    }

    /// Map an externally-captured `Instant` (e.g. a request's arrival
    /// time) onto the tracer clock.
    pub fn ns_at(&self, t: Instant) -> u64 {
        t.saturating_duration_since(self.inner.epoch).as_nanos() as u64
    }

    /// Record one span. A no-op for untraced requests (`trace == 0`).
    pub fn record(
        &self,
        trace: u64,
        kind: SpanKind,
        bank: Option<usize>,
        division: Option<usize>,
        start_ns: u64,
        dur_ns: u64,
    ) {
        if trace == 0 {
            return;
        }
        let ki = kind.index();
        self.inner.totals.ns[ki].fetch_add(dur_ns, Ordering::Relaxed);
        self.inner.totals.count[ki].fetch_add(1, Ordering::Relaxed);
        let span = Span {
            trace,
            kind,
            bank: bank.map(|b| b as u32).unwrap_or(NO_INDEX),
            division: division.map(|d| d as u32).unwrap_or(NO_INDEX),
            start_ns,
            dur_ns,
        };
        let mut ring = self.inner.ring.lock().unwrap();
        if ring.spans.len() < ring.cap {
            ring.spans.push(span);
            ring.next = ring.spans.len() % ring.cap;
        } else {
            let at = ring.next;
            ring.spans[at] = span;
            ring.next = (at + 1) % ring.cap;
            ring.wrapped = true;
            self.inner.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Spans recorded so far, oldest first. Bounded by the ring
    /// capacity; once the ring wraps the oldest spans are gone (the
    /// `dropped` counter says how many).
    pub fn snapshot(&self) -> Vec<Span> {
        let ring = self.inner.ring.lock().unwrap();
        if !ring.wrapped {
            ring.spans.clone()
        } else {
            let mut out = Vec::with_capacity(ring.spans.len());
            out.extend_from_slice(&ring.spans[ring.next..]);
            out.extend_from_slice(&ring.spans[..ring.next]);
            out
        }
    }

    /// Spans overwritten after the ring wrapped.
    pub fn dropped(&self) -> u64 {
        self.inner.dropped.load(Ordering::Relaxed)
    }

    /// Per-kind `(name, total_ns, count)` rows for exposition.
    pub fn stage_totals(&self) -> Vec<(&'static str, u64, u64)> {
        SPAN_KINDS
            .iter()
            .map(|&k| {
                let i = k.index();
                (
                    k.as_str(),
                    self.inner.totals.ns[i].load(Ordering::Relaxed),
                    self.inner.totals.count[i].load(Ordering::Relaxed),
                )
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampling_divisor_controls_admission() {
        let off = Tracer::new(0);
        for _ in 0..10 {
            assert_eq!(off.admit(), 0);
        }
        let all = Tracer::new(1);
        let ids: Vec<u64> = (0..5).map(|_| all.admit()).collect();
        assert_eq!(ids, vec![1, 2, 3, 4, 5]);
        let third = Tracer::new(3);
        let ids: Vec<u64> = (0..9).map(|_| third.admit()).collect();
        let traced: Vec<u64> = ids.iter().copied().filter(|&i| i != 0).collect();
        assert_eq!(traced, vec![3, 6, 9]);
    }

    #[test]
    fn untraced_records_are_dropped_and_ring_bounds_memory() {
        let t = Tracer::with_capacity(1, 4);
        t.record(0, SpanKind::Queue, None, None, 0, 100);
        assert!(t.snapshot().is_empty());
        for i in 1..=6u64 {
            t.record(i, SpanKind::Queue, None, None, i * 10, 1);
        }
        let spans = t.snapshot();
        assert_eq!(spans.len(), 4);
        // Oldest-first after wrap: traces 3,4,5,6 survive.
        let traces: Vec<u64> = spans.iter().map(|s| s.trace).collect();
        assert_eq!(traces, vec![3, 4, 5, 6]);
        assert_eq!(t.dropped(), 2);
    }

    #[test]
    fn stage_totals_accumulate() {
        let t = Tracer::new(1);
        t.record(1, SpanKind::Stage, Some(0), Some(2), 0, 100);
        t.record(1, SpanKind::Stage, Some(0), Some(3), 100, 50);
        t.record(2, SpanKind::Vote, None, None, 200, 7);
        let rows = t.stage_totals();
        let stage = rows.iter().find(|(n, _, _)| *n == "stage").unwrap();
        assert_eq!((stage.1, stage.2), (150, 2));
        let vote = rows.iter().find(|(n, _, _)| *n == "vote").unwrap();
        assert_eq!((vote.1, vote.2), (7, 1));
        let idle = rows.iter().find(|(n, _, _)| *n == "remote").unwrap();
        assert_eq!((idle.1, idle.2), (0, 0));
    }

    #[test]
    fn span_json_roundtrips_with_and_without_indices() {
        let s = Span {
            trace: 42,
            kind: SpanKind::Stage,
            bank: 1,
            division: 3,
            start_ns: 1000,
            dur_ns: 250,
        };
        let back = Span::from_json(&Json::parse(&s.to_json().to_string_compact()).unwrap()).unwrap();
        assert_eq!(back, s);
        let s = Span {
            trace: 7,
            kind: SpanKind::Respond,
            bank: NO_INDEX,
            division: NO_INDEX,
            start_ns: 5,
            dur_ns: 1,
        };
        let text = s.to_json().to_string_compact();
        assert!(!text.contains("bank"));
        let back = Span::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, s);
        assert!(Span::from_json(&Json::parse(r#"{"trace":1,"kind":"nope","start_ns":0,"dur_ns":0}"#).unwrap()).is_err());
    }

    #[test]
    fn clones_share_one_ring_and_clock() {
        let t = Tracer::new(1);
        let t2 = t.clone();
        let id = t.admit();
        t2.record(id, SpanKind::Admission, None, None, t.now_ns(), 10);
        assert_eq!(t.snapshot().len(), 1);
        assert!(t2.ns_at(Instant::now()) >= t.snapshot()[0].start_ns);
    }
}
