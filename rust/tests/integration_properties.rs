//! Property tests over the full compile→map→search chain on randomly
//! generated learning problems (the repository's deepest invariants).

use dt2cam::api::registry::{self, BackendOptions};
use dt2cam::api::{Dt2Cam, NativeBackend};
use dt2cam::cart::{train, train_forest, Forest, ForestParams, TrainParams};
use dt2cam::cluster::{spawn_router, spawn_worker, Placement};
use dt2cam::compiler::compile;
use dt2cam::config::EngineKind;
use dt2cam::coordinator::scheduler::Scheduler;
use dt2cam::coordinator::{
    BankSpec, Coordinator, InferenceRequest, ServingPlan, DEFAULT_PROGRAM,
};
use dt2cam::net::{Client, ServerConfig};
use dt2cam::opt::OptLevel;
use dt2cam::synth::mapping::MappedArray;
use dt2cam::synth::simulate::{simulate, SimOptions};
use dt2cam::tcam::params::DeviceParams;
use dt2cam::testkit::{property_r, Gen};
use dt2cam::util::prng::Prng;

/// Random learning problem -> every layer must agree with the tree.
#[test]
fn full_chain_equivalence_property() {
    property_r("tree == LUT == mapped == scheduler", 12, |g: &mut Gen| {
        let n = g.usize_in(30, 150);
        let f = g.usize_in(1, 6);
        let classes = g.usize_in(2, 5);
        let xs = g.matrix(n, f);
        let ys: Vec<usize> = (0..n).map(|_| g.usize_in(0, classes)).collect();
        let tree = train(&xs, &ys, classes, &TrainParams::default());
        let lut = compile(&tree);
        let p = DeviceParams::default();
        let s = g.pick(&[16usize, 32, 64]);
        let mut rng = Prng::new(g.u64());
        let m = MappedArray::from_lut(&lut, s, &p, &mut rng);
        let plan = ServingPlan::build(&m, &m.vref, &p);
        let sched = Scheduler::new(&plan, &p);

        // Random probes (in and slightly out of the training domain).
        let probes: Vec<Vec<f64>> = (0..24)
            .map(|_| (0..f).map(|_| g.f64_in(-0.1, 1.1)).collect())
            .collect();
        let queries: Vec<Vec<bool>> = probes
            .iter()
            .map(|x| m.pad_query(&lut.encode_input(x)))
            .collect();
        let out = sched
            .run_batch(&NativeBackend::new(), &queries, probes.len())
            .map_err(|e| e.to_string())?;

        for (i, x) in probes.iter().enumerate() {
            let want = tree.predict(x);
            if lut.classify(x) != Some(want) {
                return Err(format!("LUT diverged at probe {i}"));
            }
            if out.classes[i] != Some(want) {
                return Err(format!(
                    "scheduler diverged at probe {i}: {:?} vs {want}",
                    out.classes[i]
                ));
            }
        }
        Ok(())
    });
}

/// One [`BankSpec`] per tree, borrowing the mapped arrays (specs are
/// consumed by coordinator construction, so callers build them twice —
/// once per execution strategy).
fn bank_specs<'a>(forest: &Forest, arrays: &'a [MappedArray]) -> Vec<BankSpec<'a>> {
    forest
        .trees
        .iter()
        .zip(&forest.feature_sets)
        .zip(arrays)
        .map(|((t, feats), m)| {
            let lut = compile(t);
            let rows_physical = lut.n_rows();
            BankSpec {
                lut,
                features: feats.clone(),
                mapped: m,
                vref: &m.vref,
                rows_physical,
            }
        })
        .collect()
}

/// The ISSUE 5 differential harness: on seeded randomized ensemble
/// programs (1-, 3-, and 9-bank bagged forests over random learning
/// problems, varied tile sizes, varied channel depths), the streaming
/// pipelined coordinator must be **bit-identical** to the sequential
/// coordinator — classes, modeled energy, active-row counts, and the
/// per-bank energy breakdown — on every registry backend that supports
/// pipelining. Backends that cannot drive stage threads (the `Rc`-backed
/// pjrt client) skip cleanly with the registry's own message.
#[test]
fn pipelined_coordinator_bit_identical_to_sequential_across_backends() {
    let opts = BackendOptions::default();
    for kind in EngineKind::ALL {
        if let Err(e) = registry::create_pipeline_backend(kind, &opts) {
            assert!(
                !registry::pipeline_capable(kind),
                "constructor refused a pipeline-capable backend: {e:#}"
            );
            eprintln!("skipping {} in the pipelined harness: {e:#}", kind.name());
            continue;
        }
        for n_banks in [1usize, 3, 9] {
            property_r(
                &format!("pipelined == sequential ({}, {n_banks} banks)", kind.name()),
                3,
                |g: &mut Gen| {
                    let n = g.usize_in(40, 110);
                    let f = g.usize_in(2, 5);
                    let classes = g.usize_in(2, 4);
                    let xs = g.matrix(n, f);
                    let ys: Vec<usize> = (0..n).map(|_| g.usize_in(0, classes)).collect();
                    let forest = train_forest(
                        &xs,
                        &ys,
                        classes,
                        &ForestParams {
                            n_trees: n_banks,
                            sample_fraction: 0.8,
                            max_features: 2.min(f),
                            ..Default::default()
                        },
                        &mut Prng::new(g.u64()),
                    );
                    let p = DeviceParams::default();
                    let s = g.pick(&[16usize, 32, 64]);
                    let arrays: Vec<MappedArray> = forest
                        .trees
                        .iter()
                        .map(|t| {
                            MappedArray::from_lut(&compile(t), s, &p, &mut Prng::new(g.u64()))
                        })
                        .collect();
                    let batch = g.pick(&[4usize, 8]);
                    let depth = g.pick(&[1usize, 2, 4]);

                    let dispatch = registry::create_bank_dispatch(kind, &opts)
                        .map_err(|e| format!("{e:#}"))?;
                    let mut seq = Coordinator::with_banks(
                        dispatch,
                        batch,
                        bank_specs(&forest, &arrays),
                        p.clone(),
                    )
                    .map_err(|e| format!("{e:#}"))?;
                    let backend = registry::create_pipeline_backend(kind, &opts)
                        .map_err(|e| format!("{e:#}"))?;
                    let mut piped = Coordinator::with_banks_pipelined(
                        backend,
                        batch,
                        bank_specs(&forest, &arrays),
                        p.clone(),
                        depth,
                    )
                    .map_err(|e| format!("{e:#}"))?;

                    // Probes in and slightly out of the training domain.
                    let probes: Vec<Vec<f64>> = (0..g.usize_in(10, 30))
                        .map(|_| (0..f).map(|_| g.f64_in(-0.1, 1.1)).collect())
                        .collect();
                    let a = seq.classify_all(&probes).map_err(|e| format!("{e:#}"))?;
                    let b = piped.classify_all(&probes).map_err(|e| format!("{e:#}"))?;
                    if a != b {
                        return Err(format!(
                            "classes diverged (S={s}, batch={batch}, depth={depth}): {a:?} vs {b:?}"
                        ));
                    }
                    if piped.in_flight() != 0 {
                        return Err(format!("{} batches left in flight", piped.in_flight()));
                    }
                    // Hardware cost roll-ups must agree bit for bit.
                    if seq.metrics.modeled_energy != piped.metrics.modeled_energy {
                        return Err(format!(
                            "modeled energy diverged: {} vs {}",
                            seq.metrics.modeled_energy, piped.metrics.modeled_energy
                        ));
                    }
                    if seq.metrics.active_row_evals != piped.metrics.active_row_evals {
                        return Err(format!(
                            "active-row counts diverged: {} vs {}",
                            seq.metrics.active_row_evals, piped.metrics.active_row_evals
                        ));
                    }
                    if seq.metrics.bank_energy != piped.metrics.bank_energy {
                        return Err(format!(
                            "per-bank energy diverged: {:?} vs {:?}",
                            seq.metrics.bank_energy, piped.metrics.bank_energy
                        ));
                    }
                    if seq.metrics.decisions != piped.metrics.decisions
                        || seq.metrics.no_match != piped.metrics.no_match
                        || seq.metrics.multi_match != piped.metrics.multi_match
                    {
                        return Err("decision/match counters diverged".into());
                    }
                    Ok(())
                },
            );
        }
    }
}

/// The row optimizer's differential acceptance bar: on seeded 1-, 3-,
/// and 9-bank forests over real datasets, both optimizer levels must
/// preserve classification **bit-identically** on every registry
/// backend in sequential and pipelined execution; level 1 must also
/// preserve the modeled energy attribution (total, per-bank, active-row
/// counts) bit for bit, because it never touches a clean program's
/// LUTs. The row accounting stamped into the serving metrics must match
/// the optimizer's own report exactly.
#[test]
fn row_optimizer_preserves_classification_across_backends_and_modes() {
    let opts = BackendOptions::default();
    let p = DeviceParams::default();
    for (name, n_banks) in [("iris", 1usize), ("haberman", 3), ("haberman", 9)] {
        property_r(
            &format!("optimized == baseline ({name}, {n_banks} banks)"),
            2,
            |g: &mut Gen| {
                let seed = g.u64();
                let model = if n_banks == 1 {
                    Dt2Cam::dataset_seeded(name, seed).map_err(|e| format!("{e:#}"))?
                } else {
                    Dt2Cam::forest_seeded(
                        name,
                        &ForestParams {
                            n_trees: n_banks,
                            sample_fraction: 0.8,
                            max_features: 2,
                            ..Default::default()
                        },
                        seed,
                    )
                    .map_err(|e| format!("{e:#}"))?
                };
                let program = model.compile();
                let base = program.map(16, &p);
                for level in [OptLevel::L1, OptLevel::L2] {
                    let (opt_program, report) =
                        program.optimize(level).map_err(|e| format!("{e:#}"))?;
                    let optm = opt_program.map(16, &p);
                    for kind in EngineKind::ALL {
                        let mut bs = match base.session_with(kind, 8, &opts) {
                            Ok(s) => s,
                            Err(e) => {
                                eprintln!("skipping {} in the opt harness: {e:#}", kind.name());
                                continue;
                            }
                        };
                        let mut os = optm
                            .session_with(kind, 8, &opts)
                            .map_err(|e| format!("{e:#}"))?;
                        let want = bs.classify_all(&model.test_x).map_err(|e| format!("{e:#}"))?;
                        let got = os.classify_all(&model.test_x).map_err(|e| format!("{e:#}"))?;
                        if want != got {
                            return Err(format!(
                                "classes diverged under {level} on {} ({name}, {n_banks} banks)",
                                kind.name()
                            ));
                        }
                        // The optimizer's report and the serving metrics
                        // must agree on the row accounting.
                        if os.metrics().rows_total != report.rows_after as u64
                            || os.metrics().rows_physical != report.rows_physical as u64
                        {
                            return Err(format!(
                                "metrics rows {}/{} != opt report {}/{}",
                                os.metrics().rows_physical,
                                os.metrics().rows_total,
                                report.rows_physical,
                                report.rows_after
                            ));
                        }
                        if level == OptLevel::L1 {
                            // Level 1 never touches a clean LUT: energy
                            // attribution is bit-identical to baseline.
                            let (a, b) = (bs.metrics(), os.metrics());
                            if a.modeled_energy.to_bits() != b.modeled_energy.to_bits()
                                || a.active_row_evals != b.active_row_evals
                                || a.bank_energy != b.bank_energy
                            {
                                return Err(format!(
                                    "level-1 energy attribution diverged on {}",
                                    kind.name()
                                ));
                            }
                        }
                        if registry::pipeline_capable(kind) {
                            let mut op = optm
                                .session_pipelined(kind, 8, &opts, 2)
                                .map_err(|e| format!("{e:#}"))?;
                            let piped =
                                op.classify_all(&model.test_x).map_err(|e| format!("{e:#}"))?;
                            if piped != want {
                                return Err(format!(
                                    "pipelined optimized classes diverged under {level} on {}",
                                    kind.name()
                                ));
                            }
                            if op.metrics().modeled_energy.to_bits()
                                != os.metrics().modeled_energy.to_bits()
                                || op.metrics().bank_energy != os.metrics().bank_energy
                            {
                                return Err(format!(
                                    "pipelined optimized energy diverged under {level} on {}",
                                    kind.name()
                                ));
                            }
                        }
                    }
                }
                Ok(())
            },
        );
    }
}

/// The optimized artifact shards transparently: a 9-bank L2-optimized
/// haberman forest behind 3 workers and a router answers bit-identically
/// to the single-process session, and the cluster-wide metrics snapshot
/// carries the program's row accounting over the wire.
#[test]
fn optimized_program_serves_bit_identically_through_a_cluster() {
    let fp = ForestParams {
        n_trees: 9,
        sample_fraction: 0.8,
        max_features: 2,
        ..Default::default()
    };
    let model = Dt2Cam::forest_seeded("haberman", &fp, 0xD72CA0).unwrap();
    let (program, report) = model.compile().optimize(OptLevel::L2).unwrap();
    let p = DeviceParams::default();
    let map = || program.map(16, &p);

    let mapped = map();
    let (expected, energy) = {
        let mut single = mapped.session(EngineKind::Native, 1).unwrap();
        let expected = single.classify_all(&model.test_x).unwrap();
        (expected, single.metrics().energy_per_dec())
    };

    let shape =
        Placement::round_robin(9, (0..3).map(|i| format!("w{i}")).collect(), 0).unwrap();
    let workers: Vec<_> = (0..3)
        .map(|w| {
            spawn_worker(
                "127.0.0.1:0",
                ServerConfig::default(),
                map(),
                EngineKind::Native,
                1,
                BackendOptions::default(),
                shape.banks_of(w),
            )
            .unwrap()
        })
        .collect();
    let addrs: Vec<String> = workers.iter().map(|w| w.local_addr().to_string()).collect();
    let placement = Placement::round_robin(9, addrs, 0).unwrap();
    let router =
        spawn_router("127.0.0.1:0", ServerConfig::default(), mapped, 1, placement).unwrap();

    let mut client = Client::connect(&router.local_addr().to_string()).unwrap();
    for (i, x) in model.test_x.iter().enumerate() {
        assert_eq!(client.classify(x).unwrap(), expected[i], "input {i}");
    }
    let snap = client.metrics().unwrap();
    assert_eq!(snap.decisions, model.test_x.len() as u64);
    assert_eq!(
        snap.energy_per_dec.to_bits(),
        energy.to_bits(),
        "cluster energy must be bit-identical to single-process"
    );
    // Row accounting travels the wire: the router reports the optimized
    // program's logical and physical rows (not a worker double-count).
    assert_eq!(snap.rows_total, report.rows_after as u64);
    assert_eq!(snap.rows_physical, report.rows_physical as u64);
    assert!(
        report.rows_physical < report.rows_before,
        "a 9-bank haberman forest must merge or share rows: {}",
        report.summary_line()
    );

    router.shutdown().unwrap();
    for w in workers {
        w.shutdown().unwrap();
    }
}

/// Energy accounting invariants: SP <= no-SP; first division pays full.
#[test]
fn energy_invariants_property() {
    property_r("energy bounds", 10, |g: &mut Gen| {
        let n = g.usize_in(40, 120);
        let f = g.usize_in(2, 5);
        let xs = g.matrix(n, f);
        let ys: Vec<usize> = (0..n).map(|_| g.usize_in(0, 2)).collect();
        let tree = train(&xs, &ys, 2, &TrainParams::default());
        let lut = compile(&tree);
        let p = DeviceParams::default();
        let mut rng = Prng::new(g.u64());
        let m = MappedArray::from_lut(&lut, 16, &p, &mut rng);

        let probes: Vec<Vec<f64>> = (0..16)
            .map(|_| (0..f).map(|_| g.f64_in(0.0, 1.0)).collect())
            .collect();
        let labels = vec![0usize; probes.len()];
        let golden: Vec<usize> = probes.iter().map(|x| tree.predict(x)).collect();

        let sp = simulate(
            &m, &lut, &probes, &labels, &golden, &m.vref, &p,
            &SimOptions::default(),
        );
        let no_sp = simulate(
            &m, &lut, &probes, &labels, &golden, &m.vref, &p,
            &SimOptions { selective_precharge: false, ..SimOptions::default() },
        );
        if sp.energy_per_dec > no_sp.energy_per_dec + 1e-20 {
            return Err("SP increased energy".into());
        }
        // No-SP energy is exactly rows x divisions x E_row + E_mem.
        let want =
            (m.real_rows * m.n_cwd) as f64 * p.e_row_active() + p.e_mem;
        if (no_sp.energy_per_dec - want).abs() > 1e-18 {
            return Err(format!(
                "no-SP energy {} != closed form {}",
                no_sp.energy_per_dec, want
            ));
        }
        // Accuracy identical (SP is purely an energy feature).
        if sp.accuracy != no_sp.accuracy {
            return Err("SP changed accuracy".into());
        }
        Ok(())
    });
}

/// Tile-count formulas hold for arbitrary LUT geometries.
#[test]
fn tile_grid_formula_property() {
    property_r("grid covers LUT exactly", 20, |g: &mut Gen| {
        let n = g.usize_in(20, 200);
        let f = g.usize_in(1, 6);
        let xs = g.matrix(n, f);
        let ys: Vec<usize> = (0..n).map(|_| g.usize_in(0, 3)).collect();
        let lut = compile(&train(&xs, &ys, 3, &TrainParams::default()));
        let p = DeviceParams::default();
        let s = g.pick(&[16usize, 32, 64, 128]);
        let mut rng = Prng::new(g.u64());
        let m = MappedArray::from_lut(&lut, s, &p, &mut rng);

        let checks = [
            m.n_rwd == (lut.n_rows() + s - 1) / s,
            m.n_cwd == (lut.width() + 1 + s - 1) / s,
            m.padded_rows == m.n_rwd * s,
            m.padded_width == m.n_cwd * s,
            m.padded_rows >= lut.n_rows(),
            m.padded_width >= lut.width() + 1,
            m.cells.len() == m.padded_rows * m.padded_width,
            m.divisions.len() == m.n_cwd,
        ];
        if checks.iter().all(|&c| c) {
            Ok(())
        } else {
            Err(format!("geometry checks failed: {checks:?}"))
        }
    });
}

/// The encoded query always selects exactly one row on clean hardware —
/// even for out-of-range feature values.
#[test]
fn one_survivor_property() {
    property_r("exactly one survivor", 15, |g: &mut Gen| {
        let n = g.usize_in(30, 120);
        let f = g.usize_in(1, 4);
        let xs = g.matrix(n, f);
        let ys: Vec<usize> = (0..n).map(|_| g.usize_in(0, 2)).collect();
        let lut = compile(&train(&xs, &ys, 2, &TrainParams::default()));
        let p = DeviceParams::default();
        let mut rng = Prng::new(g.u64());
        let m = MappedArray::from_lut(&lut, 32, &p, &mut rng);
        for _ in 0..20 {
            // Includes far-out-of-domain probes.
            let x: Vec<f64> = (0..f).map(|_| g.f64_in(-10.0, 10.0)).collect();
            let q = m.pad_query(&lut.encode_input(&x));
            let survivors = m.digital_matches(&q);
            if survivors.len() != 1 {
                return Err(format!("{} survivors for {x:?}", survivors.len()));
            }
        }
        Ok(())
    });
}

/// The multi-tenant differential property: two seeded random forests
/// loaded as two tenants of one registry coordinator, driven by
/// *interleaved pinned* requests, must answer exactly as two solo
/// single-program coordinators do — per-tenant classes **and** the
/// per-tenant modeled-energy attribution bit-identical — on every
/// pipeline-capable registry backend, in sequential and pipelined
/// execution alike. The batcher keys batches by (program, version), so
/// each tenant sees its own probe stream in its own order: any keying
/// or attribution bug perturbs the f64 sums and fails the bit compare.
#[test]
fn two_tenant_registry_is_differentially_exact_per_tenant() {
    let opts = BackendOptions::default();
    let p = DeviceParams::default();
    for kind in EngineKind::ALL {
        if let Err(e) = registry::create_pipeline_backend(kind, &opts) {
            assert!(
                !registry::pipeline_capable(kind),
                "constructor refused a pipeline-capable backend: {e:#}"
            );
            eprintln!("skipping {} in the tenant harness: {e:#}", kind.name());
            continue;
        }
        property_r(
            &format!("two tenants == two solos ({})", kind.name()),
            3,
            |g: &mut Gen| {
                // One shared feature space so the same probes are valid
                // rows for both tenants; two independent training draws
                // so the tenants genuinely disagree.
                let n = g.usize_in(40, 110);
                let f = g.usize_in(2, 5);
                let classes = g.usize_in(2, 4);
                let xs = g.matrix(n, f);
                let ys: Vec<usize> = (0..n).map(|_| g.usize_in(0, classes)).collect();
                let fp = ForestParams {
                    n_trees: 3,
                    sample_fraction: 0.8,
                    max_features: 2.min(f),
                    ..Default::default()
                };
                let forest_a = train_forest(&xs, &ys, classes, &fp, &mut Prng::new(g.u64()));
                let forest_b = train_forest(&xs, &ys, classes, &fp, &mut Prng::new(g.u64()));
                let s = g.pick(&[16usize, 32]);
                let map_forest = |forest: &Forest, g: &mut Gen| -> Vec<MappedArray> {
                    forest
                        .trees
                        .iter()
                        .map(|t| {
                            MappedArray::from_lut(&compile(t), s, &p, &mut Prng::new(g.u64()))
                        })
                        .collect()
                };
                let arrays_a = map_forest(&forest_a, g);
                let arrays_b = map_forest(&forest_b, g);
                let batch = g.pick(&[4usize, 8]);
                let depth = g.pick(&[1usize, 2]);
                let probes: Vec<Vec<f64>> = (0..g.usize_in(10, 30))
                    .map(|_| (0..f).map(|_| g.f64_in(-0.1, 1.1)).collect())
                    .collect();

                // Solo expectations, one single-tenant coordinator each.
                let solo = |forest: &Forest,
                            arrays: &[MappedArray]|
                 -> Result<(Vec<Option<usize>>, f64), String> {
                    let dispatch = registry::create_bank_dispatch(kind, &opts)
                        .map_err(|e| format!("{e:#}"))?;
                    let mut c =
                        Coordinator::with_banks(dispatch, batch, bank_specs(forest, arrays), p.clone())
                            .map_err(|e| format!("{e:#}"))?;
                    let classes = c.classify_all(&probes).map_err(|e| format!("{e:#}"))?;
                    Ok((classes, c.metrics.modeled_energy))
                };
                let (want_a, energy_a) = solo(&forest_a, &arrays_a)?;
                let (want_b, energy_b) = solo(&forest_b, &arrays_b)?;

                // Drive one registry coordinator with the interleaved
                // two-tenant stream and compare per tenant.
                let check = |multi: &mut Coordinator, label: &str| -> Result<(), String> {
                    multi
                        .load_program("b", bank_specs(&forest_b, &arrays_b), forest_b.trees.len(), 0)
                        .map_err(|e| format!("{e:#}"))?;
                    for (i, x) in probes.iter().enumerate() {
                        // Even ids unpinned (active tenant = boot
                        // program A), odd ids pinned to "b".
                        multi.submit(InferenceRequest::new(2 * i as u64, x.clone()));
                        multi.submit(
                            InferenceRequest::new(2 * i as u64 + 1, x.clone())
                                .with_program(Some("b".into())),
                        );
                    }
                    let mut resp = multi.poll(true).map_err(|e| format!("{e:#}"))?;
                    if resp.len() != 2 * probes.len() {
                        return Err(format!(
                            "{label}: {} answers for {} requests",
                            resp.len(),
                            2 * probes.len()
                        ));
                    }
                    resp.sort_by_key(|r| r.id);
                    for (i, r) in resp.iter().enumerate() {
                        if let Some(e) = &r.error {
                            return Err(format!("{label}: request {} errored: {e}", r.id));
                        }
                        let (want, prog) = if i % 2 == 0 {
                            (want_a[i / 2], DEFAULT_PROGRAM)
                        } else {
                            (want_b[i / 2], "b")
                        };
                        if r.program != prog || r.class != want {
                            return Err(format!(
                                "{label}: request {} answered {:?} under {:?}, solo says {want:?} under {prog:?}",
                                r.id, r.class, r.program
                            ));
                        }
                    }
                    // Per-tenant energy attribution is the solo energy,
                    // to the last bit.
                    for (id, solo_energy, want_dec) in [
                        (DEFAULT_PROGRAM, energy_a, probes.len() as u64),
                        ("b", energy_b, probes.len() as u64),
                    ] {
                        let u = multi
                            .metrics
                            .per_program
                            .iter()
                            .find(|u| u.id == id)
                            .ok_or_else(|| format!("{label}: no usage row for {id:?}"))?;
                        if u.decisions != want_dec {
                            return Err(format!(
                                "{label}: {id:?} decisions {} != {want_dec}",
                                u.decisions
                            ));
                        }
                        if u.modeled_energy.to_bits() != solo_energy.to_bits() {
                            return Err(format!(
                                "{label}: {id:?} energy {} != solo {solo_energy}",
                                u.modeled_energy
                            ));
                        }
                    }
                    Ok(())
                };

                let dispatch = registry::create_bank_dispatch(kind, &opts)
                    .map_err(|e| format!("{e:#}"))?;
                let mut seq =
                    Coordinator::with_banks(dispatch, batch, bank_specs(&forest_a, &arrays_a), p.clone())
                        .map_err(|e| format!("{e:#}"))?;
                check(&mut seq, "sequential")?;

                let backend = registry::create_pipeline_backend(kind, &opts)
                    .map_err(|e| format!("{e:#}"))?;
                let mut piped = Coordinator::with_banks_pipelined(
                    backend,
                    batch,
                    bank_specs(&forest_a, &arrays_a),
                    p.clone(),
                    depth,
                )
                .map_err(|e| format!("{e:#}"))?;
                check(&mut piped, "pipelined")?;
                if piped.in_flight() != 0 {
                    return Err(format!("{} batches left in flight", piped.in_flight()));
                }
                Ok(())
            },
        );
    }
}

/// The registry's LRU bound is a *safety* bound: on random tenant
/// churn it may only ever evict a resident that is neither active nor
/// holding in-flight requests. An idle inactive tenant is evicted to
/// make room; when every slot is active or in flight, the load is
/// refused with the typed full-registry error and the registry is left
/// exactly as it was.
#[test]
fn lru_eviction_never_touches_active_or_in_flight_tenants() {
    use std::time::Duration;
    let p = DeviceParams::default();
    property_r("LRU evicts only idle inactive tenants", 6, |g: &mut Gen| {
        let n = g.usize_in(40, 100);
        let f = g.usize_in(2, 4);
        let classes = g.usize_in(2, 4);
        let xs = g.matrix(n, f);
        let ys: Vec<usize> = (0..n).map(|_| g.usize_in(0, classes)).collect();
        let fp = ForestParams {
            n_trees: 2,
            sample_fraction: 0.8,
            max_features: 0,
            ..Default::default()
        };
        let tenant = |g: &mut Gen| -> (Forest, Vec<MappedArray>) {
            let forest = train_forest(&xs, &ys, classes, &fp, &mut Prng::new(g.u64()));
            let arrays = forest
                .trees
                .iter()
                .map(|t| MappedArray::from_lut(&compile(t), 16, &p, &mut Prng::new(g.u64())))
                .collect();
            (forest, arrays)
        };
        let (boot, boot_arrays) = tenant(g);
        let (t1, t1_arrays) = tenant(g);
        let (t2, t2_arrays) = tenant(g);
        let (t3, t3_arrays) = tenant(g);

        let mut coord = Coordinator::with_banks(
            registry::create_bank_dispatch(EngineKind::Native, &BackendOptions::default())
                .map_err(|e| format!("{e:#}"))?,
            4,
            bank_specs(&boot, &boot_arrays),
            p.clone(),
        )
        .map_err(|e| format!("{e:#}"))?;
        coord.set_max_programs(2);

        // Slot 2 of 2: t1 becomes resident next to the active boot
        // program.
        coord
            .load_program("t1", bank_specs(&t1, &t1_arrays), 2, 0)
            .map_err(|e| format!("{e:#}"))?;
        // t1 is idle and inactive — loading t2 must evict it, never the
        // active boot program.
        coord
            .load_program("t2", bank_specs(&t2, &t2_arrays), 2, 0)
            .map_err(|e| format!("{e:#}"))?;
        let ids: Vec<String> = coord.program_list().iter().map(|s| s.id.clone()).collect();
        if !ids.contains(&DEFAULT_PROGRAM.to_string()) {
            return Err(format!("LRU evicted the active program: {ids:?}"));
        }
        if ids.contains(&"t1".to_string()) || !ids.contains(&"t2".to_string()) {
            return Err(format!("expected t1 evicted for t2: {ids:?}"));
        }

        // Pin a request in flight against t2 (held batch — the batcher
        // won't release a partial batch for an hour) and try to load
        // t3: both slots are now untouchable, so the load must be a
        // typed refusal that leaves the registry unchanged.
        coord.set_batch_max_wait(Duration::from_secs(3600));
        let x: Vec<f64> = (0..f).map(|_| g.f64_in(0.0, 1.0)).collect();
        coord.submit(InferenceRequest::new(0, x).with_program(Some("t2".into())));
        let err = match coord.load_program("t3", bank_specs(&t3, &t3_arrays), 2, 0) {
            Err(e) => format!("{e:#}"),
            Ok(v) => return Err(format!("full registry accepted t3 as v{v}")),
        };
        if !err.contains("registry is full") {
            return Err(format!("untyped refusal: {err}"));
        }
        let after: Vec<String> = coord.program_list().iter().map(|s| s.id.clone()).collect();
        if after != ids {
            return Err(format!("refused load mutated the registry: {ids:?} -> {after:?}"));
        }

        // Drain; t2 goes idle (still inactive), so the same load now
        // lands by evicting it.
        coord.set_batch_max_wait(Duration::ZERO);
        let resp = coord.poll(true).map_err(|e| format!("{e:#}"))?;
        if resp.len() != 1 || resp[0].error.is_some() {
            return Err(format!("pinned request did not drain clean: {resp:?}"));
        }
        coord
            .load_program("t3", bank_specs(&t3, &t3_arrays), 2, 0)
            .map_err(|e| format!("{e:#}"))?;
        let final_ids: Vec<String> =
            coord.program_list().iter().map(|s| s.id.clone()).collect();
        if !final_ids.contains(&"t3".to_string())
            || !final_ids.contains(&DEFAULT_PROGRAM.to_string())
        {
            return Err(format!("expected t2 evicted for t3: {final_ids:?}"));
        }
        Ok(())
    });
}

/// Sharded histogram recording then merging — any shard count, any
/// assignment, any merge order — must be bit-identical to pooled
/// recording: buckets, count, sum, and therefore every percentile.
/// This is the exactness claim the router's cluster-wide metrics merge
/// stands on (`obs::hist`).
#[test]
fn histogram_merge_over_arbitrary_shardings_is_bit_identical_to_pooled() {
    use dt2cam::obs::Histogram;
    property_r("sharded hist merge == pooled", 40, |g: &mut Gen| {
        let k = g.usize_in(1, 9);
        let n = g.usize_in(0, 400);
        let mut pooled = Histogram::new();
        let mut shards = vec![Histogram::new(); k];
        for _ in 0..n {
            // Uniform exponent so every log2 bucket gets exercised,
            // then a random offset inside the bucket.
            let exp = g.usize_in(0, 64) as u32;
            let lo = 1u64 << exp.min(63);
            let v = lo + g.u64() % lo;
            pooled.record(v);
            shards[g.usize_in(0, k)].record(v);
        }
        // Merge in a random order: bucket-wise addition is associative
        // and commutative, so the order must not matter.
        let mut merged = Histogram::new();
        while !shards.is_empty() {
            let i = g.usize_in(0, shards.len());
            merged.merge(&shards.remove(i));
        }
        if merged != pooled {
            return Err(format!("merged != pooled over {k} shards: {merged:?} vs {pooled:?}"));
        }
        for p in [0.0, 50.0, 95.0, 99.0, 100.0] {
            if merged.percentile(p) != pooled.percentile(p) {
                return Err(format!("p{p} diverged after merge"));
            }
        }
        Ok(())
    });
}
