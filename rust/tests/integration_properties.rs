//! Property tests over the full compile→map→search chain on randomly
//! generated learning problems (the repository's deepest invariants).

use dt2cam::api::registry::{self, BackendOptions};
use dt2cam::api::NativeBackend;
use dt2cam::cart::{train, train_forest, Forest, ForestParams, TrainParams};
use dt2cam::compiler::compile;
use dt2cam::config::EngineKind;
use dt2cam::coordinator::scheduler::Scheduler;
use dt2cam::coordinator::{BankSpec, Coordinator, ServingPlan};
use dt2cam::synth::mapping::MappedArray;
use dt2cam::synth::simulate::{simulate, SimOptions};
use dt2cam::tcam::params::DeviceParams;
use dt2cam::testkit::{property_r, Gen};
use dt2cam::util::prng::Prng;

/// Random learning problem -> every layer must agree with the tree.
#[test]
fn full_chain_equivalence_property() {
    property_r("tree == LUT == mapped == scheduler", 12, |g: &mut Gen| {
        let n = g.usize_in(30, 150);
        let f = g.usize_in(1, 6);
        let classes = g.usize_in(2, 5);
        let xs = g.matrix(n, f);
        let ys: Vec<usize> = (0..n).map(|_| g.usize_in(0, classes)).collect();
        let tree = train(&xs, &ys, classes, &TrainParams::default());
        let lut = compile(&tree);
        let p = DeviceParams::default();
        let s = g.pick(&[16usize, 32, 64]);
        let mut rng = Prng::new(g.u64());
        let m = MappedArray::from_lut(&lut, s, &p, &mut rng);
        let plan = ServingPlan::build(&m, &m.vref, &p);
        let sched = Scheduler::new(&plan, &p);

        // Random probes (in and slightly out of the training domain).
        let probes: Vec<Vec<f64>> = (0..24)
            .map(|_| (0..f).map(|_| g.f64_in(-0.1, 1.1)).collect())
            .collect();
        let queries: Vec<Vec<bool>> = probes
            .iter()
            .map(|x| m.pad_query(&lut.encode_input(x)))
            .collect();
        let out = sched
            .run_batch(&NativeBackend::new(), &queries, probes.len())
            .map_err(|e| e.to_string())?;

        for (i, x) in probes.iter().enumerate() {
            let want = tree.predict(x);
            if lut.classify(x) != Some(want) {
                return Err(format!("LUT diverged at probe {i}"));
            }
            if out.classes[i] != Some(want) {
                return Err(format!(
                    "scheduler diverged at probe {i}: {:?} vs {want}",
                    out.classes[i]
                ));
            }
        }
        Ok(())
    });
}

/// One [`BankSpec`] per tree, borrowing the mapped arrays (specs are
/// consumed by coordinator construction, so callers build them twice —
/// once per execution strategy).
fn bank_specs<'a>(forest: &Forest, arrays: &'a [MappedArray]) -> Vec<BankSpec<'a>> {
    forest
        .trees
        .iter()
        .zip(&forest.feature_sets)
        .zip(arrays)
        .map(|((t, feats), m)| BankSpec {
            lut: compile(t),
            features: feats.clone(),
            mapped: m,
            vref: &m.vref,
        })
        .collect()
}

/// The ISSUE 5 differential harness: on seeded randomized ensemble
/// programs (1-, 3-, and 9-bank bagged forests over random learning
/// problems, varied tile sizes, varied channel depths), the streaming
/// pipelined coordinator must be **bit-identical** to the sequential
/// coordinator — classes, modeled energy, active-row counts, and the
/// per-bank energy breakdown — on every registry backend that supports
/// pipelining. Backends that cannot drive stage threads (the `Rc`-backed
/// pjrt client) skip cleanly with the registry's own message.
#[test]
fn pipelined_coordinator_bit_identical_to_sequential_across_backends() {
    let opts = BackendOptions::default();
    for kind in EngineKind::ALL {
        if let Err(e) = registry::create_pipeline_backend(kind, &opts) {
            assert!(
                !registry::pipeline_capable(kind),
                "constructor refused a pipeline-capable backend: {e:#}"
            );
            eprintln!("skipping {} in the pipelined harness: {e:#}", kind.name());
            continue;
        }
        for n_banks in [1usize, 3, 9] {
            property_r(
                &format!("pipelined == sequential ({}, {n_banks} banks)", kind.name()),
                3,
                |g: &mut Gen| {
                    let n = g.usize_in(40, 110);
                    let f = g.usize_in(2, 5);
                    let classes = g.usize_in(2, 4);
                    let xs = g.matrix(n, f);
                    let ys: Vec<usize> = (0..n).map(|_| g.usize_in(0, classes)).collect();
                    let forest = train_forest(
                        &xs,
                        &ys,
                        classes,
                        &ForestParams {
                            n_trees: n_banks,
                            sample_fraction: 0.8,
                            max_features: 2.min(f),
                            ..Default::default()
                        },
                        &mut Prng::new(g.u64()),
                    );
                    let p = DeviceParams::default();
                    let s = g.pick(&[16usize, 32, 64]);
                    let arrays: Vec<MappedArray> = forest
                        .trees
                        .iter()
                        .map(|t| {
                            MappedArray::from_lut(&compile(t), s, &p, &mut Prng::new(g.u64()))
                        })
                        .collect();
                    let batch = g.pick(&[4usize, 8]);
                    let depth = g.pick(&[1usize, 2, 4]);

                    let dispatch = registry::create_bank_dispatch(kind, &opts)
                        .map_err(|e| format!("{e:#}"))?;
                    let mut seq = Coordinator::with_banks(
                        dispatch,
                        batch,
                        bank_specs(&forest, &arrays),
                        p.clone(),
                    )
                    .map_err(|e| format!("{e:#}"))?;
                    let backend = registry::create_pipeline_backend(kind, &opts)
                        .map_err(|e| format!("{e:#}"))?;
                    let mut piped = Coordinator::with_banks_pipelined(
                        backend,
                        batch,
                        bank_specs(&forest, &arrays),
                        p.clone(),
                        depth,
                    )
                    .map_err(|e| format!("{e:#}"))?;

                    // Probes in and slightly out of the training domain.
                    let probes: Vec<Vec<f64>> = (0..g.usize_in(10, 30))
                        .map(|_| (0..f).map(|_| g.f64_in(-0.1, 1.1)).collect())
                        .collect();
                    let a = seq.classify_all(&probes).map_err(|e| format!("{e:#}"))?;
                    let b = piped.classify_all(&probes).map_err(|e| format!("{e:#}"))?;
                    if a != b {
                        return Err(format!(
                            "classes diverged (S={s}, batch={batch}, depth={depth}): {a:?} vs {b:?}"
                        ));
                    }
                    if piped.in_flight() != 0 {
                        return Err(format!("{} batches left in flight", piped.in_flight()));
                    }
                    // Hardware cost roll-ups must agree bit for bit.
                    if seq.metrics.modeled_energy != piped.metrics.modeled_energy {
                        return Err(format!(
                            "modeled energy diverged: {} vs {}",
                            seq.metrics.modeled_energy, piped.metrics.modeled_energy
                        ));
                    }
                    if seq.metrics.active_row_evals != piped.metrics.active_row_evals {
                        return Err(format!(
                            "active-row counts diverged: {} vs {}",
                            seq.metrics.active_row_evals, piped.metrics.active_row_evals
                        ));
                    }
                    if seq.metrics.bank_energy != piped.metrics.bank_energy {
                        return Err(format!(
                            "per-bank energy diverged: {:?} vs {:?}",
                            seq.metrics.bank_energy, piped.metrics.bank_energy
                        ));
                    }
                    if seq.metrics.decisions != piped.metrics.decisions
                        || seq.metrics.no_match != piped.metrics.no_match
                        || seq.metrics.multi_match != piped.metrics.multi_match
                    {
                        return Err("decision/match counters diverged".into());
                    }
                    Ok(())
                },
            );
        }
    }
}

/// Energy accounting invariants: SP <= no-SP; first division pays full.
#[test]
fn energy_invariants_property() {
    property_r("energy bounds", 10, |g: &mut Gen| {
        let n = g.usize_in(40, 120);
        let f = g.usize_in(2, 5);
        let xs = g.matrix(n, f);
        let ys: Vec<usize> = (0..n).map(|_| g.usize_in(0, 2)).collect();
        let tree = train(&xs, &ys, 2, &TrainParams::default());
        let lut = compile(&tree);
        let p = DeviceParams::default();
        let mut rng = Prng::new(g.u64());
        let m = MappedArray::from_lut(&lut, 16, &p, &mut rng);

        let probes: Vec<Vec<f64>> = (0..16)
            .map(|_| (0..f).map(|_| g.f64_in(0.0, 1.0)).collect())
            .collect();
        let labels = vec![0usize; probes.len()];
        let golden: Vec<usize> = probes.iter().map(|x| tree.predict(x)).collect();

        let sp = simulate(
            &m, &lut, &probes, &labels, &golden, &m.vref, &p,
            &SimOptions::default(),
        );
        let no_sp = simulate(
            &m, &lut, &probes, &labels, &golden, &m.vref, &p,
            &SimOptions { selective_precharge: false, ..SimOptions::default() },
        );
        if sp.energy_per_dec > no_sp.energy_per_dec + 1e-20 {
            return Err("SP increased energy".into());
        }
        // No-SP energy is exactly rows x divisions x E_row + E_mem.
        let want =
            (m.real_rows * m.n_cwd) as f64 * p.e_row_active() + p.e_mem;
        if (no_sp.energy_per_dec - want).abs() > 1e-18 {
            return Err(format!(
                "no-SP energy {} != closed form {}",
                no_sp.energy_per_dec, want
            ));
        }
        // Accuracy identical (SP is purely an energy feature).
        if sp.accuracy != no_sp.accuracy {
            return Err("SP changed accuracy".into());
        }
        Ok(())
    });
}

/// Tile-count formulas hold for arbitrary LUT geometries.
#[test]
fn tile_grid_formula_property() {
    property_r("grid covers LUT exactly", 20, |g: &mut Gen| {
        let n = g.usize_in(20, 200);
        let f = g.usize_in(1, 6);
        let xs = g.matrix(n, f);
        let ys: Vec<usize> = (0..n).map(|_| g.usize_in(0, 3)).collect();
        let lut = compile(&train(&xs, &ys, 3, &TrainParams::default()));
        let p = DeviceParams::default();
        let s = g.pick(&[16usize, 32, 64, 128]);
        let mut rng = Prng::new(g.u64());
        let m = MappedArray::from_lut(&lut, s, &p, &mut rng);

        let checks = [
            m.n_rwd == (lut.n_rows() + s - 1) / s,
            m.n_cwd == (lut.width() + 1 + s - 1) / s,
            m.padded_rows == m.n_rwd * s,
            m.padded_width == m.n_cwd * s,
            m.padded_rows >= lut.n_rows(),
            m.padded_width >= lut.width() + 1,
            m.cells.len() == m.padded_rows * m.padded_width,
            m.divisions.len() == m.n_cwd,
        ];
        if checks.iter().all(|&c| c) {
            Ok(())
        } else {
            Err(format!("geometry checks failed: {checks:?}"))
        }
    });
}

/// The encoded query always selects exactly one row on clean hardware —
/// even for out-of-range feature values.
#[test]
fn one_survivor_property() {
    property_r("exactly one survivor", 15, |g: &mut Gen| {
        let n = g.usize_in(30, 120);
        let f = g.usize_in(1, 4);
        let xs = g.matrix(n, f);
        let ys: Vec<usize> = (0..n).map(|_| g.usize_in(0, 2)).collect();
        let lut = compile(&train(&xs, &ys, 2, &TrainParams::default()));
        let p = DeviceParams::default();
        let mut rng = Prng::new(g.u64());
        let m = MappedArray::from_lut(&lut, 32, &p, &mut rng);
        for _ in 0..20 {
            // Includes far-out-of-domain probes.
            let x: Vec<f64> = (0..f).map(|_| g.f64_in(-10.0, 10.0)).collect();
            let q = m.pad_query(&lut.encode_input(&x));
            let survivors = m.digital_matches(&q);
            if survivors.len() != 1 {
                return Err(format!("{} survivors for {x:?}", survivors.len()));
            }
        }
        Ok(())
    });
}
