//! Failure injection: the system must fail loudly and helpfully, never
//! silently misclassify, when its environment is broken.

use std::io::Write;

use dt2cam::config::RunConfig;
use dt2cam::runtime::Manifest;

fn tmpdir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("dt2cam_test_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn missing_artifacts_dir_mentions_make() {
    let err = Manifest::load(std::path::Path::new("/definitely/not/here")).unwrap_err();
    assert!(format!("{err:#}").contains("make artifacts"));
}

#[test]
fn corrupt_manifest_is_rejected() {
    let dir = tmpdir("corrupt");
    std::fs::write(dir.join("manifest.json"), "{not json").unwrap();
    assert!(Manifest::load(&dir).is_err());
}

#[test]
fn manifest_with_wrong_format_rejected() {
    let dir = tmpdir("wrongformat");
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"format": "protobuf", "entries": []}"#,
    )
    .unwrap();
    let err = Manifest::load(&dir).unwrap_err();
    assert!(format!("{err:#}").contains("hlo-text"));
}

#[test]
fn manifest_referencing_missing_file_rejected() {
    let dir = tmpdir("missingfile");
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"format": "hlo-text", "entries": [
            {"name": "x", "kind": "tile", "file": "gone.hlo.txt", "s": 16, "b": 1, "tiles": 1}
        ]}"#,
    )
    .unwrap();
    let err = Manifest::load(&dir).unwrap_err();
    assert!(format!("{err:#}").contains("gone.hlo.txt"));
}

#[test]
fn empty_manifest_rejected() {
    let dir = tmpdir("empty");
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"format": "hlo-text", "entries": []}"#,
    )
    .unwrap();
    assert!(Manifest::load(&dir).is_err());
}

#[test]
fn garbage_hlo_file_fails_at_compile_not_execute() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        return;
    }
    // A manifest whose file exists but contains garbage must error when
    // the executable is built, with the artifact name in the message.
    let dir = tmpdir("garbagehlo");
    let mut f = std::fs::File::create(dir.join("bad.hlo.txt")).unwrap();
    writeln!(f, "this is not HLO").unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"format": "hlo-text", "entries": [
            {"name": "bad", "kind": "tile", "file": "bad.hlo.txt", "s": 16, "b": 1, "tiles": 1}
        ]}"#,
    )
    .unwrap();
    let eng = dt2cam::runtime::MatchEngine::new(&dir).unwrap();
    let err = eng.warm_tile(16, 1).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("bad"), "{msg}");
}

#[test]
fn config_rejects_nonsense() {
    for bad in [
        r#"{"tile_size": 33}"#,
        r#"{"train_fraction": 1.5}"#,
        r#"{"saf1": -0.1}"#,
        r#"{"engine": "gpu"}"#,
        r#"{"schedule": "warp"}"#,
        r#"{"batch": 0}"#,
        r#"[1,2,3]"#,
    ] {
        assert!(RunConfig::from_json_text(bad).is_err(), "accepted: {bad}");
    }
}

#[test]
fn scheduler_rejects_wrong_query_width() {
    use dt2cam::api::NativeBackend;
    use dt2cam::coordinator::scheduler::Scheduler;
    use dt2cam::coordinator::ServingPlan;
    use dt2cam::report::workload::Workload;
    use dt2cam::tcam::params::DeviceParams;

    let w = Workload::prepare("iris").unwrap();
    let p = DeviceParams::default();
    let m = w.map(16, &p);
    let plan = ServingPlan::build(&m, &m.vref, &p);
    let sched = Scheduler::new(&plan, &p);
    let bad = vec![vec![false; 3]]; // wrong width
    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let _ = sched.run_batch(&NativeBackend::new(), &bad, 1);
    }));
    assert!(res.is_err(), "wrong-width query must be rejected");
}

#[test]
fn oversize_batch_errors_cleanly_on_pjrt() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        return;
    }
    use dt2cam::api::PjrtBackend;
    use dt2cam::coordinator::scheduler::Scheduler;
    use dt2cam::coordinator::ServingPlan;
    use dt2cam::report::workload::Workload;
    use dt2cam::tcam::params::DeviceParams;

    let w = Workload::prepare("iris").unwrap();
    let p = DeviceParams::default();
    let m = w.map(16, &p);
    let plan = ServingPlan::build(&m, &m.vref, &p);
    let sched = Scheduler::new(&plan, &p);
    let pjrt = PjrtBackend::from_dir(std::path::Path::new("artifacts")).unwrap();
    // 300 lanes: above the largest lowered batch (256).
    let queries: Vec<Vec<bool>> = (0..300).map(|_| vec![false; m.padded_width]).collect();
    let err = sched.run_batch(&pjrt, &queries, 300).unwrap_err();
    assert!(format!("{err:#}").contains("largest lowered artifact batch"));
}

#[test]
fn unknown_engine_error_lists_registry_names() {
    use dt2cam::api::registry;
    use dt2cam::config::EngineKind;

    let err = EngineKind::parse("gpu").unwrap_err();
    let msg = format!("{err:#}");
    for name in registry::names() {
        assert!(msg.contains(name), "error should list '{name}': {msg}");
    }

    // Same failure surfaced through the CLI's --engine path.
    let argv: Vec<String> = ["serve", "--dataset", "iris", "--engine", "gpu"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let cli_err = dt2cam::cli::run(argv).unwrap_err();
    let cli_msg = format!("{cli_err:#}");
    for name in registry::names() {
        assert!(cli_msg.contains(name), "CLI error should list '{name}': {cli_msg}");
    }
}

#[test]
fn unknown_dataset_is_a_clean_error() {
    let err = dt2cam::dataset::catalog::by_name("imagenet", 0).unwrap_err();
    assert!(format!("{err:#}").contains("available"));
}

#[test]
fn cli_unknown_flag_rejected() {
    let argv: Vec<String> = ["report", "--frobnicate"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    assert!(dt2cam::cli::run(argv).is_err());
}
