//! Integration: the full DT2CAM flow per dataset, across tile sizes and
//! backends. The load-bearing invariant everywhere is the paper's §IV.B
//! claim — ideal hardware reproduces the software tree ("golden") exactly.

use dt2cam::api::{MatchBackend, NativeBackend, PjrtBackend, ThreadedNativeBackend};
use dt2cam::config::{EngineKind, RunConfig};
use dt2cam::coordinator::{Coordinator, Scheduler, ServingPlan};
use dt2cam::report::workload::Workload;
use dt2cam::synth::simulate::{simulate, SimOptions};
use dt2cam::tcam::params::DeviceParams;

fn golden_everywhere(name: &str, s: usize) {
    let w = Workload::prepare(name).unwrap();
    let p = DeviceParams::default();
    let m = w.map(s, &p);

    // 1. Digital LUT search == tree.
    for (x, g) in w.test_x.iter().zip(&w.golden) {
        assert_eq!(w.lut.classify(x), Some(*g), "{name} LUT vs tree");
    }

    // 2. Functional (analog) simulation == golden.
    let r = simulate(
        &m, &w.lut, &w.test_x, &w.test_y, &w.golden, &m.vref, &p,
        &SimOptions { max_inputs: 256, ..SimOptions::default() },
    );
    assert_eq!(r.golden_agreement, 1.0, "{name} S={s} simulate vs golden");
    assert_eq!(r.no_match, 0);
    assert_eq!(r.multi_match, 0);

    // 3. Serving scheduler (native backend) == golden.
    let plan = ServingPlan::build(&m, &m.vref, &p);
    let sched = Scheduler::new(&plan, &p);
    let take = w.test_x.len().min(64);
    let queries: Vec<Vec<bool>> = w.test_x[..take]
        .iter()
        .map(|x| m.pad_query(&w.lut.encode_input(x)))
        .collect();
    let out = sched
        .run_batch(&NativeBackend::new(), &queries, take)
        .unwrap();
    for i in 0..take {
        assert_eq!(out.classes[i], Some(w.golden[i]), "{name} scheduler lane {i}");
    }
}

#[test]
fn iris_all_tile_sizes() {
    for s in [16, 32, 64, 128] {
        golden_everywhere("iris", s);
    }
}

#[test]
fn haberman_multi_division() {
    golden_everywhere("haberman", 16);
    golden_everywhere("haberman", 32);
}

#[test]
fn cancer_wide_features() {
    golden_everywhere("cancer", 16);
    golden_everywhere("cancer", 64);
}

#[test]
fn car_multiclass() {
    golden_everywhere("car", 16);
    golden_everywhere("car", 128);
}

#[test]
fn diabetes_and_titanic() {
    golden_everywhere("diabetes", 64);
    golden_everywhere("titanic", 128);
}

#[test]
fn covid_large() {
    golden_everywhere("covid", 128);
}

#[test]
fn coordinator_full_roundtrip_native() {
    let w = Workload::prepare("car").unwrap();
    let p = DeviceParams::default();
    let m = w.map(32, &p);
    let cfg = RunConfig {
        dataset: "car".into(),
        tile_size: 32,
        batch: 32,
        engine: EngineKind::Native,
        ..RunConfig::default()
    };
    let vref = m.vref.clone();
    let mut coord = Coordinator::new(&cfg, w.lut.clone(), &m, &vref, p).unwrap();
    let got = coord.classify_all(&w.test_x).unwrap();
    for (c, g) in got.iter().zip(&w.golden) {
        assert_eq!(*c, Some(*g));
    }
    assert_eq!(coord.metrics.decisions as usize, w.test_x.len());
}

#[test]
fn pjrt_engine_full_agreement() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let w = Workload::prepare("haberman").unwrap();
    let p = DeviceParams::default();
    for s in [16usize, 64] {
        let m = w.map(s, &p);
        let plan = ServingPlan::build(&m, &m.vref, &p);
        let sched = Scheduler::new(&plan, &p);
        let pjrt = PjrtBackend::from_dir(std::path::Path::new("artifacts")).unwrap();
        let take = w.test_x.len().min(32);
        let queries: Vec<Vec<bool>> = w.test_x[..take]
            .iter()
            .map(|x| m.pad_query(&w.lut.encode_input(x)))
            .collect();
        let native = sched
            .run_batch(&NativeBackend::new(), &queries, take)
            .unwrap();
        let got = sched.run_batch(&pjrt, &queries, take).unwrap();
        assert_eq!(native.classes, got.classes, "S={s}");
        assert_eq!(native.active_row_evals, got.active_row_evals, "S={s}");
    }
}

#[test]
fn sequential_equals_pipelined_outcomes() {
    use dt2cam::coordinator::pipeline::run_pipeline;
    use std::sync::Arc;
    let w = Workload::prepare("diabetes").unwrap();
    let p = DeviceParams::default();
    let m = w.map(16, &p);
    assert!(m.n_cwd > 1);
    let plan = Arc::new(ServingPlan::build(&m, &m.vref, &p));
    let batches: Vec<(Vec<Vec<bool>>, usize)> = w.test_x[..w.test_x.len().min(60)]
        .chunks(20)
        .map(|chunk| {
            let qs: Vec<Vec<bool>> = chunk
                .iter()
                .map(|x| m.pad_query(&w.lut.encode_input(x)))
                .collect();
            let n = qs.len();
            (qs, n)
        })
        .collect();
    // Both Send + Sync backends must pipe to the sequential outcome.
    for backend in [
        Arc::new(NativeBackend::new()) as Arc<dyn MatchBackend + Send + Sync>,
        Arc::new(ThreadedNativeBackend::new(4)),
    ] {
        let piped = run_pipeline(Arc::clone(&plan), backend, batches.clone(), 2).unwrap();
        let sched = Scheduler::new(&plan, &p);
        for (i, (qs, real)) in batches.iter().enumerate() {
            let seq = sched.run_batch(&NativeBackend::new(), qs, *real).unwrap();
            assert!(piped[i].error.is_none(), "batch {i} carried a stage error");
            assert_eq!(piped[i].classes, seq.classes, "batch {i}");
            assert_eq!(piped[i].active_row_evals, seq.active_row_evals, "batch {i}");
            assert_eq!(piped[i].modeled_energy, seq.modeled_energy, "batch {i}");
        }
    }
}

#[test]
fn pipelined_session_equals_sequential_session_end_to_end() {
    // The facade-level differential: `session_pipelined` (streaming
    // bank × stage pipeline behind the coordinator seam) against the
    // plain `session`, on a 3-bank forest, per pipeline-capable engine.
    use dt2cam::api::{registry, BackendOptions, Dt2Cam};
    use dt2cam::cart::ForestParams;
    use dt2cam::config::EngineKind;

    let fp = ForestParams {
        n_trees: 3,
        sample_fraction: 0.8,
        max_features: 2,
        ..Default::default()
    };
    let model = Dt2Cam::forest("haberman", &fp).unwrap();
    let mapped = model.compile().map(16, &DeviceParams::default());
    let opts = BackendOptions::default();
    for engine in EngineKind::ALL {
        if !registry::pipeline_capable(engine) {
            eprintln!("skipping {}: cannot drive the stage pipeline", engine.name());
            continue;
        }
        let mut seq = mapped.session(engine, 8).unwrap();
        let mut piped = mapped.session_pipelined(engine, 8, &opts, 2).unwrap();
        assert!(piped.pipelined());
        let a = seq.classify_all(&model.test_x).unwrap();
        let b = piped.classify_all(&model.test_x).unwrap();
        assert_eq!(a, b, "engine {}", engine.name());
        assert_eq!(
            seq.metrics().modeled_energy,
            piped.metrics().modeled_energy,
            "engine {}",
            engine.name()
        );
        assert_eq!(
            seq.metrics().active_row_evals,
            piped.metrics().active_row_evals
        );
    }
}
