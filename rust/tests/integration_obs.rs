//! Observability integration tests (`dt2cam::obs` behind the wire):
//! a server spawned with `trace_sample: 1` must produce the full
//! admission → queue → dispatch → bank-match (or per-division stage)
//! → vote → respond span chain for a traced request, echo the trace id
//! in the response frame, and answer `ObsScrape` with a Prometheus-style
//! text exposition whose stage totals parse back out; an untraced
//! server must answer the same scrape with counters only.

use std::collections::{BTreeMap, BTreeSet};
use std::net::TcpStream;

use dt2cam::api::{BackendOptions, Dt2Cam};
use dt2cam::cart::ForestParams;
use dt2cam::config::EngineKind;
use dt2cam::net::{read_frame, write_frame, Client, Frame, Server, ServerConfig};
use dt2cam::obs::{parse_stage_totals, Span, SpanKind};
use dt2cam::tcam::params::DeviceParams;

/// The 3-bank haberman forest @S=16 used across the wire tests, plus
/// the per-bank column-division counts (the pipelined stage fan-out).
fn spawn_forest_server(
    cfg: ServerConfig,
    pipelined: bool,
) -> (
    dt2cam::net::ServerHandle,
    Vec<Vec<f64>>,
    Vec<Option<usize>>,
    Vec<usize>,
) {
    let fp = ForestParams {
        n_trees: 3,
        sample_fraction: 0.8,
        max_features: 2,
        ..Default::default()
    };
    let engine = EngineKind::Native;
    let model = Dt2Cam::forest("haberman", &fp).unwrap();
    let mapped = model.compile().map(16, &DeviceParams::default());
    let divisions: Vec<usize> = mapped.banks.iter().map(|b| b.mapped.n_cwd).collect();
    let expected = mapped
        .session(engine, 8)
        .unwrap()
        .classify_all(&model.test_x)
        .unwrap();
    let opts = BackendOptions::default();
    let server = Server::spawn("127.0.0.1:0", cfg, move || {
        let session = if pipelined {
            mapped.session_pipelined(engine, 8, &opts, 2)?
        } else {
            mapped.session_with(engine, 8, &opts)?
        };
        Ok(session.into_coordinator())
    })
    .unwrap();
    (server, model.test_x, expected, divisions)
}

/// Group spans by trace id, keeping per-trace kind sets.
fn by_trace(spans: &[Span]) -> BTreeMap<u64, Vec<&Span>> {
    let mut m: BTreeMap<u64, Vec<&Span>> = BTreeMap::new();
    for s in spans {
        m.entry(s.trace).or_default().push(s);
    }
    m
}

fn kinds_of(spans: &[&Span]) -> BTreeSet<&'static str> {
    spans.iter().map(|s| s.kind.as_str()).collect()
}

#[test]
fn traced_sequential_serving_produces_the_full_span_chain_and_scrape() {
    let (server, inputs, expected, _) = spawn_forest_server(
        ServerConfig {
            trace_sample: 1,
            ..Default::default()
        },
        false,
    );
    let addr = server.local_addr().to_string();

    // Raw frames so the response's trace echo is observable: with
    // sampling 1 every admitted request must come back carrying the
    // trace id its spans were recorded under.
    let n = 12usize.min(inputs.len());
    let mut stream = TcpStream::connect(&addr).unwrap();
    let mut echoed = BTreeSet::new();
    for (i, x) in inputs[..n].iter().enumerate() {
        write_frame(
            &mut stream,
            &Frame::Request {
                id: i as u64,
                features: x.clone(),
                program: None,
            },
        )
        .unwrap();
        match read_frame(&mut stream).unwrap() {
            Frame::Response {
                id, class, trace, ..
            } => {
                assert_eq!(id, i as u64);
                assert_eq!(class, expected[i], "input {i}");
                let t = trace.expect("trace_sample 1 must echo a trace id");
                assert!(t != 0, "trace id 0 is the untraced sentinel");
                assert!(echoed.insert(t), "trace ids must be distinct, got {t} twice");
            }
            other => panic!("expected a response, got {other:?}"),
        }
    }

    let (text, spans) = Client::connect(&addr).unwrap().obs_scrape(4096).unwrap();

    // Scrape text: counters, histograms, tracer rows — and the stage
    // totals parse back out with every taxonomy stage of this mode.
    assert!(text.contains(&format!("dt2cam_requests_total {n}")), "{text}");
    assert!(text.contains("dt2cam_latency_ns_count"), "{text}");
    assert!(text.contains("dt2cam_batch_size_count"), "{text}");
    assert!(text.contains("dt2cam_trace_sample 1"), "{text}");
    let stages: BTreeSet<String> = parse_stage_totals(&text)
        .into_iter()
        .inspect(|(stage, ns, count)| {
            assert!(*count > 0, "stage {stage} counted no spans");
            assert!(*ns > 0 || stage == "admission", "stage {stage} has zero total time");
        })
        .map(|(stage, _, _)| stage)
        .collect();
    for want in ["admission", "queue", "dispatch", "bank_match", "vote", "respond"] {
        assert!(stages.contains(want), "scrape lacks stage {want}: {stages:?}");
    }

    // Span ring: every echoed trace is present, and at least one trace
    // carries the complete admission → respond chain with a bank-match
    // span per bank (batch-level spans are recorded under the batch's
    // representative trace; closed-loop single-connection traffic makes
    // every batch single-request, so every chain should be complete).
    let grouped = by_trace(&spans);
    for t in &echoed {
        assert!(grouped.contains_key(t), "no spans for echoed trace {t}");
    }
    let full = grouped
        .values()
        .find(|spans| {
            kinds_of(spans).is_superset(&BTreeSet::from([
                "admission", "queue", "dispatch", "bank_match", "vote", "respond",
            ]))
        })
        .expect("at least one trace must carry the full span chain");
    let banks: BTreeSet<u32> = full
        .iter()
        .filter(|s| s.kind == SpanKind::BankMatch)
        .map(|s| s.bank)
        .collect();
    assert_eq!(banks, BTreeSet::from([0, 1, 2]), "one bank-match span per bank");
    let admission = full.iter().find(|s| s.kind == SpanKind::Admission).unwrap();
    let respond = full.iter().find(|s| s.kind == SpanKind::Respond).unwrap();
    assert!(
        admission.start_ns <= respond.start_ns,
        "admission must start before respond: {admission:?} vs {respond:?}"
    );

    server.shutdown().unwrap();
}

#[test]
fn pipelined_tracing_emits_one_stage_span_per_division_per_bank() {
    let (server, inputs, expected, divisions) = spawn_forest_server(
        ServerConfig {
            trace_sample: 1,
            ..Default::default()
        },
        true,
    );
    let addr = server.local_addr().to_string();

    let mut client = Client::connect(&addr).unwrap();
    let n = 8usize.min(inputs.len());
    for (i, x) in inputs[..n].iter().enumerate() {
        assert_eq!(client.classify(x).unwrap(), expected[i], "input {i}");
    }

    let (text, spans) = Client::connect(&addr).unwrap().obs_scrape(4096).unwrap();
    assert!(
        parse_stage_totals(&text).iter().any(|(s, _, _)| s == "stage"),
        "pipelined scrape must total the stage spans: {text}"
    );

    // Find a trace with stage spans and check the fan-out: exactly one
    // span per column division of every bank (the pipeline runs one
    // stage thread per division, each recording once per traced batch).
    let grouped = by_trace(&spans);
    let (trace, stage_spans) = grouped
        .iter()
        .map(|(t, spans)| {
            (
                t,
                spans
                    .iter()
                    .filter(|s| s.kind == SpanKind::Stage)
                    .collect::<Vec<_>>(),
            )
        })
        .find(|(_, stage)| !stage.is_empty())
        .expect("some traced batch must have stage spans");
    let mut per_bank: BTreeMap<u32, Vec<u32>> = BTreeMap::new();
    for s in &stage_spans {
        per_bank.entry(s.bank).or_default().push(s.division);
    }
    assert_eq!(
        per_bank.len(),
        divisions.len(),
        "trace {trace} must cross every bank's pipeline: {per_bank:?}"
    );
    for (bank, mut divs) in per_bank {
        divs.sort_unstable();
        let want: Vec<u32> = (0..divisions[bank as usize] as u32).collect();
        assert_eq!(
            divs, want,
            "bank {bank} must record exactly one stage span per division"
        );
    }

    // The surrounding chain is still there in pipelined mode.
    let full = grouped
        .values()
        .find(|spans| {
            kinds_of(spans).is_superset(&BTreeSet::from([
                "admission", "queue", "dispatch", "stage", "vote", "respond",
            ]))
        })
        .expect("at least one trace must carry the full pipelined chain");
    assert!(!full.is_empty());

    server.shutdown().unwrap();
}

#[test]
fn untraced_server_scrapes_counters_only_and_echoes_no_trace() {
    let (server, inputs, expected, _) =
        spawn_forest_server(ServerConfig::default(), false);
    let addr = server.local_addr().to_string();

    let mut stream = TcpStream::connect(&addr).unwrap();
    write_frame(
        &mut stream,
        &Frame::Request {
            id: 0,
            features: inputs[0].clone(),
            program: None,
        },
    )
    .unwrap();
    match read_frame(&mut stream).unwrap() {
        Frame::Response { class, trace, .. } => {
            assert_eq!(class, expected[0]);
            assert_eq!(trace, None, "trace_sample 0 must not assign trace ids");
        }
        other => panic!("expected a response, got {other:?}"),
    }

    let (text, spans) = Client::connect(&addr).unwrap().obs_scrape(4096).unwrap();
    assert!(text.contains("dt2cam_requests_total 1"), "{text}");
    assert!(
        !text.contains("dt2cam_trace_sample"),
        "no tracer rows without tracing: {text}"
    );
    assert!(parse_stage_totals(&text).is_empty());
    assert!(spans.is_empty(), "no tracer, no spans: {spans:?}");

    server.shutdown().unwrap();
}
