//! Integration: non-ideality behaviors end-to-end (paper §IV.B, Fig 7).

use dt2cam::nonideal::{inject_saf, perturb_vref, SafRates};
use dt2cam::report::workload::Workload;
use dt2cam::synth::simulate::{simulate, SimOptions};
use dt2cam::tcam::params::DeviceParams;
use dt2cam::util::prng::Prng;

fn sim_with(
    w: &Workload,
    s: usize,
    saf: f64,
    sigma_sa: f64,
    sigma_in: f64,
    seed: u64,
) -> f64 {
    let p = DeviceParams::default();
    let mut rng = Prng::new(seed);
    let mut m = w.map(s, &p);
    inject_saf(&mut m, &SafRates::both(saf), &mut rng.fork(1));
    let vref = perturb_vref(&m.vref, sigma_sa, &mut rng.fork(2));
    let mut noise = rng.fork(3);
    let inputs: Vec<Vec<f64>> = w
        .test_x
        .iter()
        .map(|row| {
            row.iter()
                .map(|&v| v + noise.normal_scaled(0.0, sigma_in))
                .collect()
        })
        .collect();
    let r = simulate(
        &m, &w.lut, &inputs, &w.test_y, &w.golden, &vref, &p,
        &SimOptions { max_inputs: 256, ..SimOptions::default() },
    );
    r.accuracy
}

#[test]
fn zero_nonidealities_reproduce_golden() {
    for name in ["iris", "haberman", "cancer"] {
        let w = Workload::prepare(name).unwrap();
        let acc = sim_with(&w, 16, 0.0, 0.0, 0.0, 1);
        let golden_capped = {
            // simulate caps at 256 inputs; compute golden on same subset.
            let n = w.test_x.len().min(256);
            w.golden[..n]
                .iter()
                .zip(&w.test_y[..n])
                .filter(|(g, y)| g == y)
                .count() as f64
                / n as f64
        };
        assert!((acc - golden_capped).abs() < 1e-12, "{name}");
    }
}

#[test]
fn heavy_saf_destroys_accuracy() {
    let w = Workload::prepare("cancer").unwrap();
    let clean = sim_with(&w, 64, 0.0, 0.0, 0.0, 2);
    let broken = sim_with(&w, 64, 5.0, 0.0, 0.0, 2);
    assert!(
        broken < clean - 0.05,
        "5% SAF should visibly hurt: clean {clean}, broken {broken}"
    );
}

#[test]
fn extreme_sa_variability_hurts() {
    let w = Workload::prepare("haberman").unwrap();
    let clean = sim_with(&w, 16, 0.0, 0.0, 0.0, 3);
    // σ = 0.2 V swamps the dynamic range at S=16 (~0.55 V V_fm−V_1mm gap
    // midpointed) — far beyond the paper's worst 0.1 V case.
    let noisy = sim_with(&w, 16, 0.0, 0.2, 0.0, 3);
    assert!(noisy <= clean, "clean {clean}, noisy {noisy}");
}

#[test]
fn input_noise_degrades_gracefully() {
    let w = Workload::prepare("cancer").unwrap();
    let clean = sim_with(&w, 16, 0.0, 0.0, 0.0, 4);
    let slight = sim_with(&w, 16, 0.0, 0.0, 0.001, 4);
    let heavy = sim_with(&w, 16, 0.0, 0.0, 0.5, 4);
    // Tiny noise must stay close to clean (paper: robust encoding).
    assert!((clean - slight).abs() < 0.1, "clean {clean} slight {slight}");
    // Massive noise must cost something.
    assert!(heavy <= clean, "heavy noise cannot help: {heavy} vs {clean}");
}

#[test]
fn saf_monotone_on_average() {
    // Averaged over seeds, higher fault rates lose more accuracy.
    let w = Workload::prepare("haberman").unwrap();
    let avg = |saf: f64| -> f64 {
        (0..5).map(|t| sim_with(&w, 16, saf, 0.0, 0.0, 100 + t)).sum::<f64>() / 5.0
    };
    let a0 = avg(0.0);
    let a1 = avg(1.0);
    let a5 = avg(5.0);
    assert!(a0 >= a1 - 0.02, "0% {a0} vs 1% {a1}");
    assert!(a1 >= a5 - 0.02, "1% {a1} vs 5% {a5}");
}

#[test]
fn faults_can_produce_no_match_and_multi_match() {
    // With many faults the CAM loses the exactly-one-survivor property;
    // the simulator must report it rather than crash.
    let w = Workload::prepare("iris").unwrap();
    let p = DeviceParams::default();
    let mut rng = Prng::new(9);
    let mut m = w.map(16, &p);
    inject_saf(&mut m, &SafRates::both(20.0 / 100.0 * 100.0), &mut rng);
    let r = simulate(
        &m, &w.lut, &w.test_x, &w.test_y, &w.golden, &m.vref, &p,
        &SimOptions::default(),
    );
    assert_eq!(r.n_inputs, w.test_x.len());
    assert!(r.no_match + r.multi_match > 0, "20% SAF must break matches");
}

#[test]
fn vref_variability_is_per_sa_not_global() {
    // Two different SAs must receive different offsets.
    let nominal = vec![0.4; 64];
    let got = perturb_vref(&nominal, 0.05, &mut Prng::new(5));
    let distinct: std::collections::HashSet<u64> =
        got.iter().map(|v| v.to_bits()).collect();
    assert!(distinct.len() > 32);
}
