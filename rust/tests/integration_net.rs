//! Loopback integration tests for the wire-level serving subsystem
//! (`dt2cam::net`): a spawned socket server answering concurrent
//! clients must produce exactly the predictions the in-process
//! coordinator produces, shed load past the admission bound instead of
//! buffering unboundedly, survive malformed frames, and drain in-flight
//! requests on graceful shutdown — registry-wide where the backend
//! allows it (the `!Send` pjrt client is built *on* the server's
//! scheduler thread, so it serves too when artifacts exist).

use std::io::Write;
use std::net::TcpStream;
use std::time::Duration;

use dt2cam::api::{registry, BackendOptions, Dt2Cam};
use dt2cam::cart::ForestParams;
use dt2cam::config::EngineKind;
use dt2cam::net::{
    encode_frame, read_frame, write_frame, Client, ClientError, Frame, Server, ServerConfig,
    MAX_FRAME_LEN,
};
use dt2cam::tcam::params::DeviceParams;

/// Spawn a socket server over a 3-bank bagged forest on haberman
/// (@S=16, the acceptance-criterion program) and return the handle, the
/// test inputs, and the in-process expected predictions.
fn spawn_forest_server(
    engine: EngineKind,
    batch: usize,
    cfg: ServerConfig,
) -> (
    dt2cam::net::ServerHandle,
    Vec<Vec<f64>>,
    Vec<Option<usize>>,
) {
    let fp = ForestParams {
        n_trees: 3,
        sample_fraction: 0.8,
        max_features: 2,
        ..Default::default()
    };
    let model = Dt2Cam::forest("haberman", &fp).unwrap();
    let mapped = model.compile().map(16, &DeviceParams::default());
    let expected = mapped
        .session(engine, batch)
        .unwrap()
        .classify_all(&model.test_x)
        .unwrap();
    let opts = BackendOptions::default();
    let server = Server::spawn("127.0.0.1:0", cfg, move || {
        Ok(mapped.session_with(engine, batch, &opts)?.into_coordinator())
    })
    .unwrap();
    (server, model.test_x, expected)
}

fn has_pjrt_artifacts() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

/// Same 3-bank forest program, served through the **streaming
/// pipelined** coordinator (`serve --listen --pipelined`). The expected
/// predictions are deliberately computed by the *sequential* in-process
/// session — the acceptance criterion is that the pipelined wire path
/// answers with exactly those classes.
fn spawn_pipelined_forest_server(
    engine: EngineKind,
    batch: usize,
    cfg: ServerConfig,
    depth: usize,
) -> (
    dt2cam::net::ServerHandle,
    Vec<Vec<f64>>,
    Vec<Option<usize>>,
) {
    let fp = ForestParams {
        n_trees: 3,
        sample_fraction: 0.8,
        max_features: 2,
        ..Default::default()
    };
    let model = Dt2Cam::forest("haberman", &fp).unwrap();
    let mapped = model.compile().map(16, &DeviceParams::default());
    let expected = mapped
        .session(engine, batch)
        .unwrap()
        .classify_all(&model.test_x)
        .unwrap();
    let opts = BackendOptions::default();
    let server = Server::spawn("127.0.0.1:0", cfg, move || {
        Ok(mapped
            .session_pipelined(engine, batch, &opts, depth)?
            .into_coordinator())
    })
    .unwrap();
    (server, model.test_x, expected)
}

#[test]
fn concurrent_clients_get_exactly_the_in_process_answers_registry_wide() {
    for engine in EngineKind::ALL {
        if engine == EngineKind::Pjrt && !has_pjrt_artifacts() {
            eprintln!("skipping pjrt: run `make artifacts`");
            continue;
        }
        let (server, inputs, expected) =
            spawn_forest_server(engine, 8, ServerConfig::default());
        let addr = server.local_addr().to_string();
        let n_clients = 4;
        // Each client owns a disjoint stripe of the test split; the
        // requests interleave on the wire, so the server's batcher
        // coalesces lanes *across connections* — the answers must still
        // be exactly the in-process ones, routed back to whoever asked.
        let got: Vec<Vec<(usize, Option<usize>)>> = std::thread::scope(|s| {
            (0..n_clients)
                .map(|c| {
                    let addr = addr.clone();
                    let inputs = &inputs;
                    s.spawn(move || {
                        let mut client = Client::connect(&addr).unwrap();
                        let mut out = Vec::new();
                        let mut i = c;
                        while i < inputs.len() {
                            out.push((i, client.classify(&inputs[i]).unwrap()));
                            i += n_clients;
                        }
                        out
                    })
                })
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect()
        });
        for stripe in got {
            for (i, class) in stripe {
                assert_eq!(class, expected[i], "engine {} input {i}", engine.name());
            }
        }

        // The metrics frame reflects the whole run, across connections.
        let mut probe = Client::connect(&addr).unwrap();
        let snap = probe.metrics().unwrap();
        assert_eq!(snap.decisions, inputs.len() as u64, "{}", engine.name());
        assert_eq!(snap.requests, inputs.len() as u64);
        assert_eq!(snap.shed, 0);
        assert_eq!(snap.n_banks, 3);
        assert!(snap.energy_per_dec > 0.0);
        assert!(snap.modeled_latency > 0.0);
        assert!(
            snap.latency_p50 > 0.0 && snap.latency_p50 <= snap.latency_p99,
            "percentiles must be ordered: {snap:?}"
        );
        assert!(snap.connections >= n_clients as u64);

        let report = server.shutdown().unwrap();
        assert_eq!(report.metrics.decisions, inputs.len() as u64);
        assert_eq!(report.shed, 0);
    }
}

#[test]
fn pipelined_wire_serving_answers_concurrent_clients_with_sequential_classes() {
    // The ISSUE 5 acceptance test: `serve --listen --pipelined` on a
    // 3-bank forest, 4 concurrent wire clients, a *tiny* stage-channel
    // depth (1) so batches genuinely queue inside the pipeline — every
    // admitted request must come back exactly once, with its own id,
    // carrying exactly the class the sequential in-process
    // `classify_all` produces. Runs on every pipeline-capable registry
    // backend; the rest skip cleanly.
    for engine in EngineKind::ALL {
        if !registry::pipeline_capable(engine) {
            eprintln!(
                "skipping {}: backend cannot drive the stage pipeline",
                engine.name()
            );
            continue;
        }
        let (server, inputs, expected) =
            spawn_pipelined_forest_server(engine, 8, ServerConfig::default(), 1);
        let addr = server.local_addr().to_string();
        let n_clients = 4;
        let got: Vec<Vec<(usize, Option<usize>)>> = std::thread::scope(|s| {
            (0..n_clients)
                .map(|c| {
                    let addr = addr.clone();
                    let inputs = &inputs;
                    s.spawn(move || {
                        let mut client = Client::connect(&addr).unwrap();
                        let mut out = Vec::new();
                        let mut i = c;
                        while i < inputs.len() {
                            out.push((i, client.classify(&inputs[i]).unwrap()));
                            i += n_clients;
                        }
                        out
                    })
                })
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().unwrap())
                .collect()
        });
        let mut answered = 0usize;
        for stripe in got {
            for (i, class) in stripe {
                assert_eq!(class, expected[i], "engine {} input {i}", engine.name());
                answered += 1;
            }
        }
        assert_eq!(answered, inputs.len(), "every request answered exactly once");

        // The snapshot sees the pipelined coordinator's roll-ups.
        let mut probe = Client::connect(&addr).unwrap();
        let snap = probe.metrics().unwrap();
        assert_eq!(snap.decisions, inputs.len() as u64, "{}", engine.name());
        assert_eq!(snap.shed, 0);
        assert_eq!(snap.n_banks, 3);

        let report = server.shutdown().unwrap();
        assert_eq!(report.metrics.decisions, inputs.len() as u64);
        assert_eq!(report.metrics.stage_errors, 0);
        assert!(report.metrics.modeled_pipe_throughput > 0.0);
    }
}

#[test]
fn pipelined_graceful_shutdown_drains_batches_already_inside_the_pipeline() {
    // Batch width 4, stage-channel depth 1, hour-long partial-batch
    // deadline: the two full batches (ids 0..8) release into the
    // pipeline immediately, the trailing partial (ids 8..11) is held by
    // the batcher. The wire shutdown must answer all 11 exactly once —
    // the in-pipeline batches via the drain, the partial via the forced
    // flush — before the connection closes.
    let (server, inputs, expected) = spawn_pipelined_forest_server(
        EngineKind::Native,
        4,
        ServerConfig {
            admission: 64,
            batch_max_wait: Some(Duration::from_secs(3600)),
            ..Default::default()
        },
        1,
    );
    let addr = server.local_addr().to_string();
    let mut stream = TcpStream::connect(&addr).unwrap();
    let total = 11u64;
    for id in 0..total {
        write_frame(
            &mut stream,
            &Frame::Request {
                id,
                features: inputs[id as usize].clone(),
                program: None,
            },
        )
        .unwrap();
    }
    // Let the scheduler release the full batches into the pipeline so
    // the shutdown genuinely finds batches *inside* the stages.
    std::thread::sleep(Duration::from_millis(100));
    write_frame(&mut stream, &Frame::Shutdown).unwrap();

    let mut seen = std::collections::HashMap::new();
    loop {
        match read_frame(&mut stream) {
            Ok(Frame::Response { id, class, .. }) => {
                assert!(
                    seen.insert(id, class).is_none(),
                    "request {id} answered twice"
                );
            }
            Ok(other) => panic!("unexpected frame during drain: {other:?}"),
            Err(e) => {
                assert!(e.is_fatal(), "non-fatal error mid-drain: {e}");
                break;
            }
        }
    }
    assert_eq!(seen.len(), total as usize, "every admitted request answered");
    for (id, class) in seen {
        assert_eq!(class, expected[id as usize], "request {id}");
    }
    let report = server.join().unwrap();
    assert_eq!(report.metrics.decisions, total);
    assert_eq!(report.shed, 0);
}

#[test]
fn malformed_truncated_and_oversize_frames_get_typed_errors_and_the_connection_survives() {
    let (server, inputs, expected) =
        spawn_forest_server(EngineKind::Native, 4, ServerConfig::default());
    let addr = server.local_addr().to_string();
    let mut stream = TcpStream::connect(&addr).unwrap();

    let roundtrip_ok = |stream: &mut TcpStream| {
        write_frame(
            stream,
            &Frame::Request {
                id: 7,
                features: inputs[0].clone(),
                program: None,
            },
        )
        .unwrap();
        match read_frame(stream).unwrap() {
            Frame::Response { id, class, .. } => {
                assert_eq!(id, 7);
                assert_eq!(class, expected[0]);
            }
            other => panic!("expected a response, got {other:?}"),
        }
    };

    // 1. Unknown frame type: typed error, connection survives.
    let mut bytes = encode_frame(&Frame::Shutdown);
    bytes[5] = 0xEE;
    stream.write_all(&bytes).unwrap();
    match read_frame(&mut stream).unwrap() {
        Frame::Error { message, .. } => {
            assert!(message.contains("0xee"), "{message}")
        }
        other => panic!("expected an error frame, got {other:?}"),
    }
    roundtrip_ok(&mut stream);

    // 2. Wrong protocol version: typed error naming both versions.
    let mut bytes = encode_frame(&Frame::MetricsRequest);
    bytes[4] = 9;
    stream.write_all(&bytes).unwrap();
    match read_frame(&mut stream).unwrap() {
        Frame::Error { message, .. } => {
            assert!(message.contains('9') && message.contains('1'), "{message}")
        }
        other => panic!("expected an error frame, got {other:?}"),
    }
    roundtrip_ok(&mut stream);

    // 3. Garbage JSON payload behind a valid header.
    let body = b"\x01\x01{definitely not json";
    let mut bytes = Vec::new();
    bytes.extend_from_slice(&(body.len() as u32).to_be_bytes());
    bytes.extend_from_slice(body);
    stream.write_all(&bytes).unwrap();
    assert!(matches!(read_frame(&mut stream).unwrap(), Frame::Error { .. }));
    roundtrip_ok(&mut stream);

    // 4. A request with too few features: typed error carrying the id.
    write_frame(
        &mut stream,
        &Frame::Request {
            id: 42,
            features: vec![0.5],
            program: None,
        },
    )
    .unwrap();
    match read_frame(&mut stream).unwrap() {
        Frame::Error { id, message } => {
            assert_eq!(id, Some(42));
            assert!(message.contains("features"), "{message}");
        }
        other => panic!("expected an error frame, got {other:?}"),
    }
    roundtrip_ok(&mut stream);

    // 5. Oversize frame: the server skips the declared payload, answers
    // a typed error, and the connection still works.
    let len = MAX_FRAME_LEN + 64;
    let mut bytes = Vec::with_capacity(4 + len);
    bytes.extend_from_slice(&(len as u32).to_be_bytes());
    bytes.resize(4 + len, 0);
    stream.write_all(&bytes).unwrap();
    match read_frame(&mut stream).unwrap() {
        Frame::Error { message, .. } => {
            assert!(message.contains("exceeds"), "{message}")
        }
        other => panic!("expected an error frame, got {other:?}"),
    }
    roundtrip_ok(&mut stream);

    // 6. Truncated frame: this connection is unrecoverable (the server
    // drops it)... but the *server* survives and keeps serving others.
    let mut doomed = TcpStream::connect(&addr).unwrap();
    doomed.write_all(&100u32.to_be_bytes()).unwrap();
    doomed.write_all(&[1, 1, b'{']).unwrap();
    drop(doomed); // EOF mid-frame on the server side
    std::thread::sleep(Duration::from_millis(50));
    roundtrip_ok(&mut stream);

    // The error counter saw the recoverable rejections.
    let mut probe = Client::connect(&addr).unwrap();
    let snap = probe.metrics().unwrap();
    assert!(snap.protocol_errors >= 4, "{snap:?}");

    let report = server.shutdown().unwrap();
    assert!(report.protocol_errors >= 4);
}

#[test]
fn admission_overflow_sheds_and_graceful_shutdown_drains_in_flight() {
    // Admission bound 2, batch width 64, and an hour-long batch
    // deadline: admitted requests sit in the batcher (nothing releases
    // them), so the 3rd..5th requests must shed deterministically, and
    // only the shutdown drain answers the first two.
    let (server, inputs, expected) = spawn_forest_server(
        EngineKind::Native,
        64,
        ServerConfig {
            admission: 2,
            batch_max_wait: Some(Duration::from_secs(3600)),
            ..Default::default()
        },
    );
    let addr = server.local_addr().to_string();
    let mut stream = TcpStream::connect(&addr).unwrap();
    for id in 0..5u64 {
        write_frame(
            &mut stream,
            &Frame::Request {
                id,
                features: inputs[id as usize % inputs.len()].clone(),
                program: None,
            },
        )
        .unwrap();
    }
    // Exactly the overflow (ids 2, 3, 4) comes back shed, in order —
    // the admitted pair is *held*, not answered and not buffered past
    // the bound.
    for want in 2..5u64 {
        match read_frame(&mut stream).unwrap() {
            Frame::Shed { id } => assert_eq!(id, want),
            other => panic!("expected shed for {want}, got {other:?}"),
        }
    }
    assert_eq!(server.shed_count(), 3);

    // Graceful shutdown: the drain answers the two in-flight requests
    // before the connection closes.
    write_frame(&mut stream, &Frame::Shutdown).unwrap();
    for want in 0..2u64 {
        match read_frame(&mut stream).unwrap() {
            Frame::Response { id, class, .. } => {
                assert_eq!(id, want);
                assert_eq!(class, expected[want as usize]);
            }
            other => panic!("expected drained response for {want}, got {other:?}"),
        }
    }
    // ...and then EOF.
    assert!(read_frame(&mut stream).unwrap_err().is_fatal());

    let report = server.join().unwrap();
    assert_eq!(report.shed, 3);
    assert_eq!(report.metrics.decisions, 2);
    assert_eq!(report.metrics.requests, 2, "shed requests are never admitted");
}

#[test]
fn client_reconnects_transparently_and_loadgens_report_latency() {
    let (server, inputs, expected) =
        spawn_forest_server(EngineKind::ThreadedNative, 8, ServerConfig::default());
    let addr = server.local_addr().to_string();

    // Transparent reconnect: kill the client's socket in place; the
    // next classify must redial and still answer correctly.
    let mut client = Client::connect(&addr).unwrap();
    assert_eq!(client.classify(&inputs[0]).unwrap(), expected[0]);
    client.sever_for_test();
    assert_eq!(
        client.classify(&inputs[1]).unwrap(),
        expected[1],
        "classify must survive a dropped connection via reconnect"
    );

    // Closed-loop load: every request answered, percentiles ordered.
    let report = dt2cam::net::closed_loop(&addr, &inputs, 3, 60).unwrap();
    assert_eq!(report.completed, 60);
    assert_eq!(report.errors, 0);
    assert!(report.p50 > 0.0 && report.p50 <= report.p95 && report.p95 <= report.p99);
    assert!(report.throughput() > 0.0);

    // Open-loop at a modest target rate: all answered too (the rate is
    // far below capacity, so sheds would indicate a bug here with the
    // default admission bound).
    let report = dt2cam::net::open_loop(&addr, &inputs, 2, 500.0, 50).unwrap();
    assert_eq!(report.completed + report.shed, 50);
    assert_eq!(report.errors, 0);
    assert_eq!(report.shed, 0);

    let typed_shed = ClientError::Shed { id: 9 };
    assert!(typed_shed.to_string().contains("admission"));
    server.shutdown().unwrap();
}

#[test]
fn wire_shutdown_via_client_stops_the_server_and_join_returns_rollups() {
    let (server, inputs, _) =
        spawn_forest_server(EngineKind::Native, 8, ServerConfig::default());
    let addr = server.local_addr().to_string();
    let mut client = Client::connect(&addr).unwrap();
    for x in inputs.iter().take(5) {
        client.classify(x).unwrap();
    }
    // Shutdown over the wire (the CI smoke path), not via the handle.
    Client::connect(&addr).unwrap().shutdown().unwrap();
    let report = server.join().unwrap();
    assert_eq!(report.metrics.decisions, 5);
}
