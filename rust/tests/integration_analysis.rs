//! Integration tests for the static program verifier (`dt2cam check` /
//! `analysis::verify_*`): every repo-produced program must verify
//! clean, and seeded row-level mutations of a clean artifact must be
//! flagged (the verifier's recall, measured end to end through the
//! JSON round-trip).

use dt2cam::analysis;
use dt2cam::api::{CompiledProgram, Dt2Cam};
use dt2cam::cart::ForestParams;
use dt2cam::compiler::Trit;
use dt2cam::tcam::params::DeviceParams;
use dt2cam::util::prng::Prng;

/// Fail with the full diagnostic list, not just the counts.
fn assert_clean(report: &analysis::AnalysisReport, ctx: &str) {
    if report.n_errors() > 0 || report.n_warnings() > 0 {
        for d in &report.diagnostics {
            eprintln!("{ctx}: {d}");
        }
        panic!("{ctx}: {}", report.summary_line());
    }
}

/// Every shipped dataset's single-tree program verifies clean at both
/// stages (compiled and mapped). Credit is excluded on runtime grounds
/// (120k instances; covid's 33k is the established ceiling for the
/// debug-profile suites — see `integration_pipeline::covid_large`).
#[test]
fn all_dataset_programs_verify_clean() {
    for name in [
        "iris", "diabetes", "haberman", "car", "cancer", "titanic", "covid",
    ] {
        let model = Dt2Cam::dataset(name).unwrap();
        let program = model.compile();
        assert_clean(&analysis::verify_compiled(&program), name);
        let mapped = program.map(64, &DeviceParams::default());
        assert_clean(&analysis::verify_mapped(&mapped), name);
    }
}

/// Forest programs (3 and 9 banks) on two datasets and two training
/// seeds verify clean — bagging, feature projection and per-bank
/// mapping seeds all stay inside the invariants.
#[test]
fn forest_programs_verify_clean_across_seeds() {
    for name in ["iris", "haberman"] {
        for n_trees in [3usize, 9] {
            for seed in [dt2cam::api::EXPERIMENT_SEED, 20260808] {
                let fp = ForestParams {
                    n_trees,
                    sample_fraction: 0.8,
                    max_features: 2,
                    ..ForestParams::default()
                };
                let model = Dt2Cam::forest_seeded(name, &fp, seed).unwrap();
                let program = model.compile();
                let ctx = format!("{name} x{n_trees} seed {seed}");
                assert_clean(&analysis::verify_compiled(&program), &ctx);
                let mapped = program.map(16, &DeviceParams::default());
                assert_clean(&analysis::verify_mapped(&mapped), &ctx);
            }
        }
    }
}

/// Mutation testing of the verifier itself: seeded row-level mutations
/// of a clean compiled artifact — a flipped trit, a relabeled class, a
/// swapped row pair, a nudged rule threshold — must be flagged as
/// errors (or refuse to load) after a JSON round-trip. Requires >= 90%
/// recall over the mutation corpus.
#[test]
fn seeded_row_mutations_are_flagged() {
    let model = Dt2Cam::dataset("iris").unwrap();
    let program = model.compile();
    assert_clean(&analysis::verify_compiled(&program), "pristine iris");

    let mut rng = Prng::new(0xC0FFEE);
    let mut total = 0usize;
    let mut flagged = 0usize;
    for _ in 0..60 {
        let mut mutant = program.clone();
        let b = rng.below(mutant.banks.len());
        let lut = &mut mutant.banks[b].lut;
        let n_rows = lut.n_rows();
        let r = rng.below(n_rows);
        match rng.below(4) {
            // Flip one stored trit (cycle so the cell always changes).
            0 => {
                let c = rng.below(lut.stored[r].len());
                lut.stored[r][c] = match lut.stored[r][c] {
                    Trit::Zero => Trit::One,
                    Trit::One => Trit::X,
                    Trit::X => Trit::Zero,
                };
            }
            // Relabel one row's class.
            1 => lut.classes[r] = (lut.classes[r] + 1) % lut.n_classes,
            // Swap two distinct stored rows (classes stay put).
            2 => {
                if n_rows < 2 {
                    continue;
                }
                let r2 = (r + 1 + rng.below(n_rows - 1)) % n_rows;
                if lut.stored[r] == lut.stored[r2] {
                    continue; // identical patterns: not a mutation
                }
                lut.stored.swap(r, r2);
            }
            // Nudge one finite rule threshold in the reduced table.
            _ => {
                let Some(rule) = lut
                    .reduced
                    .get_mut(r)
                    .and_then(|row| row.rules.iter_mut().find(|ru| ru.th1.is_finite()))
                else {
                    continue;
                };
                rule.th1 += 0.05;
            }
        }
        total += 1;
        // Round-trip through the artifact JSON: a mutation that the
        // loader already refuses counts as flagged too.
        let caught = match CompiledProgram::from_json(&mutant.to_json()) {
            Err(_) => true,
            Ok(p) => analysis::verify_compiled(&p).n_errors() > 0,
        };
        if caught {
            flagged += 1;
        }
    }
    assert!(total >= 40, "mutation corpus too small: {total}");
    assert!(
        flagged * 10 >= total * 9,
        "verifier recall below 90%: flagged {flagged} of {total} mutants"
    );
}

/// Mapped-level mutations are flagged by the mapping lint: a flipped
/// cell byte is drift (warning), a broken vref or geometry is an error.
#[test]
fn mapped_mutations_are_flagged() {
    let model = Dt2Cam::dataset("iris").unwrap();
    let mut mapped = model.compile().map(16, &DeviceParams::default());

    // Nominal grid drift: corrupt one real-row cell.
    let mut drifted = mapped.clone();
    drifted.banks[0].mapped.cells[1] ^= 1;
    let report = analysis::verify_mapped(&drifted);
    assert!(
        report
            .diagnostics
            .iter()
            .any(|d| d.check == "cell-drift"),
        "{}",
        report.summary_line()
    );

    // Broken sensing reference: an error, not a warning.
    mapped.banks[0].mapped.vref[0] = f64::NAN;
    let report = analysis::verify_mapped(&mapped);
    assert!(report.n_errors() > 0, "{}", report.summary_line());
}
