//! Online-lifecycle integration tests (`dt2cam serve` admin plane):
//! hot-swapping the active program **under concurrent load** must be
//! invisible to clients except for the response stamps. Four
//! closed-loop clients hammer a live socket server while a second
//! 3-bank forest is loaded and activated mid-run; every request must be
//! answered exactly once, with zero Shed/Error frames, and every
//! response's class must be bit-identical to the in-process
//! `classify_all` of whichever program version its admission stamp
//! names. The same harness then runs behind the cluster router
//! (bank-sharded workers swap too). Admin-plane negatives ride along:
//! a corrupt or verifier-rejected artifact is refused with a typed
//! error naming it and leaves the registry untouched, activating an
//! unknown id is refused, and a full single-slot registry refuses a
//! second tenant instead of evicting the active one.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use dt2cam::api::{BackendOptions, Dt2Cam, MappedProgram};
use dt2cam::cart::ForestParams;
use dt2cam::cluster::{spawn_router, spawn_worker, Placement};
use dt2cam::config::EngineKind;
use dt2cam::coordinator::DEFAULT_PROGRAM;
use dt2cam::net::{
    ClassifyAnswer, Client, ClientError, Server, ServerConfig, ServerHandle,
};
use dt2cam::tcam::params::DeviceParams;

fn has_pjrt_artifacts() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

/// Two *different* 3-bank bagged forests on the same dataset and seed
/// (haberman @S=16): identical test split and feature space, different
/// bootstrap/feature-subset draws — so a response answered by the wrong
/// program version shows up as a class mismatch, not a shape error.
fn two_programs() -> (MappedProgram, MappedProgram, Vec<Vec<f64>>) {
    let p = DeviceParams::default();
    let fa = ForestParams {
        n_trees: 3,
        sample_fraction: 0.8,
        max_features: 2,
        ..Default::default()
    };
    let model_a = Dt2Cam::forest("haberman", &fa).unwrap();
    let mapped_a = model_a.compile().map(16, &p);
    let fb = ForestParams {
        n_trees: 3,
        sample_fraction: 0.6,
        max_features: 1,
        ..Default::default()
    };
    let model_b = Dt2Cam::forest("haberman", &fb).unwrap();
    let mapped_b = model_b.compile().map(16, &p);
    (mapped_a, mapped_b, model_a.test_x)
}

/// Drive `total` closed-loop requests from 4 concurrent clients against
/// `addr` (request k carries input `k % inputs.len()`, striped across
/// clients). The client thread that answers request number `swap_at`
/// runs `swap` inline — mid-run, with the other three clients still
/// sending — then keeps going. Every request must succeed: a Shed or
/// Error frame anywhere fails the test, which *is* the
/// "zero swap-attributable refusals" criterion. Returns every
/// `(input index, answer)` observed.
fn drive_with_swap(
    addr: &str,
    inputs: &[Vec<f64>],
    total: usize,
    swap_at: usize,
    swap: impl FnOnce() + Send + 'static,
) -> Vec<(usize, ClassifyAnswer)> {
    let n_clients = 4;
    let answered = AtomicUsize::new(0);
    let trigger: Mutex<Option<Box<dyn FnOnce() + Send>>> =
        Mutex::new(Some(Box::new(swap)));
    std::thread::scope(|s| {
        (0..n_clients)
            .map(|c| {
                let answered = &answered;
                let trigger = &trigger;
                s.spawn(move || {
                    let mut client = Client::connect(addr).unwrap();
                    let mut out = Vec::new();
                    let mut k = c;
                    while k < total {
                        let i = k % inputs.len();
                        let ans = client.classify_pinned(&inputs[i], None).unwrap();
                        out.push((i, ans));
                        // Count *answered* requests (not sent ones) so
                        // the swap provably lands after `swap_at` full
                        // round trips — mid-run by construction.
                        let done = answered.fetch_add(1, Ordering::AcqRel) + 1;
                        if done >= swap_at {
                            if let Some(f) = trigger.lock().unwrap().take() {
                                f();
                            }
                        }
                        k += n_clients;
                    }
                    out
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect()
    })
}

/// The differential criterion: each answer's class must equal the
/// in-process expectation of the program version its stamp names —
/// version 1 = boot program (`DEFAULT_PROGRAM`), version 2 = the
/// swapped-in tenant `"b"` — and the run must have observed both sides
/// of the swap (otherwise the trigger never fired mid-run).
fn check_differential(
    answers: &[(usize, ClassifyAnswer)],
    expected_a: &[Option<usize>],
    expected_b: &[Option<usize>],
    label: &str,
) {
    let (mut before, mut after) = (0usize, 0usize);
    for (i, ans) in answers {
        match ans.program.as_str() {
            p if p == DEFAULT_PROGRAM => {
                assert_eq!(ans.pversion, 1, "{label}: boot program version");
                assert_eq!(
                    ans.class, expected_a[*i],
                    "{label}: input {i} answered under {p:?} v{}",
                    ans.pversion
                );
                before += 1;
            }
            "b" => {
                assert_eq!(ans.pversion, 2, "{label}: swapped program version");
                assert_eq!(
                    ans.class, expected_b[*i],
                    "{label}: input {i} answered under \"b\" v{}",
                    ans.pversion
                );
                after += 1;
            }
            other => panic!("{label}: unexpected program stamp {other:?}"),
        }
    }
    assert!(before > 0, "{label}: no request was served before the swap");
    assert!(after > 0, "{label}: no request was served after the swap");
}

#[test]
fn hot_swap_under_load_is_differentially_exact_registry_wide() {
    for engine in EngineKind::ALL {
        if engine == EngineKind::Pjrt && !has_pjrt_artifacts() {
            eprintln!("skipping pjrt: run `make artifacts`");
            continue;
        }
        let (mapped_a, mapped_b, inputs) = two_programs();
        let batch = 8;
        let expected_a = mapped_a
            .session(engine, batch)
            .unwrap()
            .classify_all(&inputs)
            .unwrap();
        let expected_b = mapped_b
            .session(engine, batch)
            .unwrap()
            .classify_all(&inputs)
            .unwrap();

        let boot = mapped_a.clone();
        let opts = BackendOptions::default();
        let server = Server::spawn("127.0.0.1:0", ServerConfig::default(), move || {
            Ok(boot.session_with(engine, batch, &opts)?.into_coordinator())
        })
        .unwrap();
        let addr = server.local_addr().to_string();

        let total = inputs.len() * 2;
        let artifact = mapped_b.to_json();
        let admin_addr = addr.clone();
        let answers = drive_with_swap(&addr, &inputs, total, total / 3, move || {
            // Load-then-activate over the wire, on a fresh connection —
            // exactly what `dt2cam load` + `dt2cam activate` do.
            let mut admin = Client::connect(&admin_addr).unwrap();
            let listed = admin.load_program("b", &artifact).unwrap();
            assert_eq!(listed.len(), 2, "load makes the tenant resident");
            let listed = admin.activate_program("b").unwrap();
            assert!(
                listed.iter().any(|p| p.id == "b" && p.active && p.version == 2),
                "activate flips the active id: {listed:?}"
            );
        });

        // Exactly once: 4 clients × their stripes, every request
        // answered (a lost or doubled response would change the count).
        assert_eq!(answers.len(), total, "{}", engine.name());
        check_differential(&answers, &expected_a, &expected_b, engine.name());

        // Per-tenant attribution adds up over the wire.
        let mut client = Client::connect(&addr).unwrap();
        let snap = client.metrics().unwrap();
        assert_eq!(snap.decisions, total as u64, "{}", engine.name());
        assert_eq!(snap.shed, 0, "{}", engine.name());
        let usage: u64 = snap.per_program.iter().map(|u| u.decisions).sum();
        assert_eq!(usage, total as u64, "{}: per-program decisions roll up", engine.name());
        assert!(
            snap.per_program.iter().any(|u| u.id == "b" && u.decisions > 0),
            "{}: swapped tenant shows usage: {:?}",
            engine.name(),
            snap.per_program
        );
        drop(client);

        let report = server.shutdown().unwrap();
        assert_eq!(report.shed, 0, "{}", engine.name());
        assert_eq!(report.dropped_responses, 0, "{}", engine.name());
        assert_eq!(report.metrics.decisions, total as u64, "{}", engine.name());
    }
}

#[test]
fn hot_swap_under_load_behind_cluster_router() {
    let engine = EngineKind::Native;
    let batch = 8;
    let (mapped_a, mapped_b, inputs) = two_programs();
    let expected_a = mapped_a
        .session(engine, batch)
        .unwrap()
        .classify_all(&inputs)
        .unwrap();
    let expected_b = mapped_b
        .session(engine, batch)
        .unwrap()
        .classify_all(&inputs)
        .unwrap();

    // 3 single-bank workers + router (the integration_cluster idiom:
    // shape the placement on fake names, then rebuild it with the real
    // port-0 addresses).
    let n_workers = 3;
    let shape = Placement::round_robin(
        3,
        (0..n_workers).map(|i| format!("w{i}")).collect(),
        0,
    )
    .unwrap();
    let workers: Vec<ServerHandle> = (0..n_workers)
        .map(|w| {
            spawn_worker(
                "127.0.0.1:0",
                ServerConfig::default(),
                mapped_a.clone(),
                engine,
                batch,
                BackendOptions::default(),
                shape.banks_of(w),
            )
            .unwrap()
        })
        .collect();
    let worker_addrs: Vec<String> =
        workers.iter().map(|w| w.local_addr().to_string()).collect();
    let placement = Placement::round_robin(3, worker_addrs.clone(), 0).unwrap();
    let router = spawn_router(
        "127.0.0.1:0",
        ServerConfig::default(),
        mapped_a.clone(),
        batch,
        placement,
    )
    .unwrap();
    let addr = router.local_addr().to_string();

    let total = inputs.len() * 2;
    let artifact = mapped_b.to_json();
    let router_addr = addr.clone();
    let answers = drive_with_swap(&addr, &inputs, total, total / 3, move || {
        // Cluster swap order: load everywhere first (workers, then the
        // router), activate the workers, and flip the router *last* —
        // from the first router-side "b" admission on, every BankBatch
        // names a program the workers already hold, so no batch can hit
        // an identity refusal mid-swap.
        let mut worker_admins: Vec<Client> = worker_addrs
            .iter()
            .map(|a| Client::connect(a).unwrap())
            .collect();
        for admin in &mut worker_admins {
            admin.load_program("b", &artifact).unwrap();
        }
        let mut router_admin = Client::connect(&router_addr).unwrap();
        router_admin.load_program("b", &artifact).unwrap();
        for admin in &mut worker_admins {
            admin.activate_program("b").unwrap();
        }
        let listed = router_admin.activate_program("b").unwrap();
        assert!(
            listed.iter().any(|p| p.id == "b" && p.active),
            "router activates the swapped tenant: {listed:?}"
        );
    });

    assert_eq!(answers.len(), total);
    check_differential(&answers, &expected_a, &expected_b, "cluster");

    let report = router.shutdown().unwrap();
    assert_eq!(report.shed, 0, "router shed");
    assert_eq!(report.dropped_responses, 0, "router dropped");
    assert_eq!(report.metrics.decisions, total as u64);
    for w in workers {
        let wr = w.shutdown().unwrap();
        assert_eq!(wr.dropped_responses, 0, "worker dropped");
    }
}

/// Unwrap the typed-error arm of an admin call.
fn server_error(r: Result<Vec<dt2cam::net::ProgramInfo>, ClientError>) -> String {
    match r {
        Err(ClientError::Server { message, .. }) => message,
        other => panic!("expected a typed server error, got {other:?}"),
    }
}

#[test]
fn admin_negatives_answer_typed_and_leave_the_registry_untouched() {
    let engine = EngineKind::Native;
    let (mapped_a, mapped_b, _inputs) = two_programs();
    let boot = mapped_a.clone();
    let opts = BackendOptions::default();
    let server = Server::spawn("127.0.0.1:0", ServerConfig::default(), move || {
        Ok(boot.session_with(engine, 8, &opts)?.into_coordinator())
    })
    .unwrap();
    let mut client = Client::connect(&server.local_addr().to_string()).unwrap();

    // (a) Not an artifact at all: refused, error names the id.
    let junk = dt2cam::config::Json::obj(vec![(
        "hello",
        dt2cam::config::Json::str("world".to_string()),
    )]);
    let msg = server_error(client.load_program("junk", &junk));
    assert!(msg.contains("\"junk\""), "error names the id: {msg}");
    assert!(
        msg.contains("parsing mapped-program artifact"),
        "error says why: {msg}"
    );

    // (b) Parses but fails the static verifier (one flipped row class
    // breaks path↔row bijectivity): the verify-on-load Deny gate
    // refuses it before it ever becomes resident.
    let mut evil = mapped_b.clone();
    let n = evil.program.banks[0].lut.n_classes;
    let c = &mut evil.program.banks[0].lut.classes[0];
    *c = (*c + 1) % n;
    let msg = server_error(client.load_program("evil", &evil.to_json()));
    assert!(msg.contains("\"evil\""), "error names the id: {msg}");
    assert!(
        msg.contains("failed static verification"),
        "error names the gate: {msg}"
    );

    // (c) Activating something that was never loaded is refused and the
    // refusal names both the ghost and the residents.
    let msg = server_error(client.activate_program("ghost"));
    assert!(
        msg.contains("cannot activate unknown program") && msg.contains("\"ghost\""),
        "{msg}"
    );

    // After all three refusals the registry is exactly the boot state.
    let listed = client.programs().unwrap();
    assert_eq!(listed.len(), 1);
    assert_eq!(listed[0].id, DEFAULT_PROGRAM);
    assert!(listed[0].active);
    assert_eq!(listed[0].version, 1);

    // And the untouched registry still serves.
    let x = vec![0.5; 3];
    let ans = client.classify_pinned(&x, None).unwrap();
    assert_eq!(ans.program, DEFAULT_PROGRAM);
    drop(client);
    server.shutdown().unwrap();
}

#[test]
fn single_slot_registry_refuses_a_second_tenant_instead_of_evicting_the_active_one() {
    let engine = EngineKind::Native;
    let (mapped_a, mapped_b, _inputs) = two_programs();
    let boot = mapped_a.clone();
    let opts = BackendOptions::default();
    let cfg = ServerConfig {
        max_programs: 1,
        ..Default::default()
    };
    let server = Server::spawn("127.0.0.1:0", cfg, move || {
        Ok(boot.session_with(engine, 8, &opts)?.into_coordinator())
    })
    .unwrap();
    let mut client = Client::connect(&server.local_addr().to_string()).unwrap();

    // The only resident is active; LRU may never evict it, so the load
    // is refused with the typed full-registry error — not accepted, not
    // a silent swap.
    let msg = server_error(client.load_program("b", &mapped_b.to_json()));
    assert!(msg.contains("program registry is full"), "{msg}");
    assert!(msg.contains("\"b\""), "refusal names the rejected id: {msg}");

    let listed = client.programs().unwrap();
    assert_eq!(listed.len(), 1, "registry untouched: {listed:?}");
    assert_eq!(listed[0].id, DEFAULT_PROGRAM);
    drop(client);
    server.shutdown().unwrap();
}
