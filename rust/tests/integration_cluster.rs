//! Cluster integration tests (`dt2cam::cluster`): a 9-bank forest
//! sharded over 3 worker processes behind a frontend router must be
//! indistinguishable from single-process serving — bit-identical
//! classes *and* bit-identical modeled energy accounting — and must
//! degrade the way the design promises when workers die: replicated
//! banks fail over with zero dropped admitted requests, unreplicated
//! banks answer typed error frames promptly instead of hanging.

use std::time::Duration;

use dt2cam::api::{BackendOptions, Dt2Cam, MappedProgram};
use dt2cam::cart::ForestParams;
use dt2cam::cluster::{spawn_router, spawn_worker, Placement};
use dt2cam::config::EngineKind;
use dt2cam::net::{Client, ClientError, ServerConfig, ServerHandle};
use dt2cam::tcam::params::DeviceParams;

fn has_pjrt_artifacts() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

struct Cluster {
    router: ServerHandle,
    workers: Vec<ServerHandle>,
    inputs: Vec<Vec<f64>>,
    expected: Vec<Option<usize>>,
    /// `energy_per_dec()` of the single-process session that produced
    /// `expected` (same batch width as the cluster).
    energy_per_dec: f64,
}

/// Train the acceptance-criterion program — a 9-bank bagged forest on
/// haberman @S=16 — compute the single-process expectations, then
/// stand up `n_workers` workers plus a router placing the banks
/// round-robin with `replicas` failover copies. `MappedProgram` isn't
/// `Clone`, but mapping is deterministic per (seed, S, bank), so each
/// process re-maps the same compiled program — exactly the shared
/// `compile --save` artifact of the multi-process flow.
fn spawn_cluster(engine: EngineKind, batch: usize, n_workers: usize, replicas: usize) -> Cluster {
    let fp = ForestParams {
        n_trees: 9,
        sample_fraction: 0.8,
        max_features: 2,
        ..Default::default()
    };
    let model = Dt2Cam::forest("haberman", &fp).unwrap();
    let program = model.compile();
    let p = DeviceParams::default();
    let map = || -> MappedProgram { program.map(16, &p) };

    let mapped = map();
    let (expected, energy_per_dec) = {
        let mut single = mapped.session(engine, batch).unwrap();
        let expected = single.classify_all(&model.test_x).unwrap();
        (expected, single.metrics().energy_per_dec())
    };

    // The bank layout depends only on worker *indices*, so shape it
    // before the real addresses exist (workers bind port 0).
    let shape = Placement::round_robin(
        9,
        (0..n_workers).map(|i| format!("w{i}")).collect(),
        replicas,
    )
    .unwrap();
    let workers: Vec<ServerHandle> = (0..n_workers)
        .map(|w| {
            spawn_worker(
                "127.0.0.1:0",
                ServerConfig::default(),
                map(),
                engine,
                batch,
                BackendOptions::default(),
                shape.banks_of(w),
            )
            .unwrap()
        })
        .collect();
    let addrs: Vec<String> = workers.iter().map(|w| w.local_addr().to_string()).collect();
    let placement = Placement::round_robin(9, addrs, replicas).unwrap();
    let router = spawn_router("127.0.0.1:0", ServerConfig::default(), mapped, batch, placement)
        .unwrap();
    Cluster {
        router,
        workers,
        inputs: model.test_x,
        expected,
        energy_per_dec,
    }
}

#[test]
fn three_workers_answer_bit_identically_to_single_process_registry_wide() {
    // Batch width 1 on both sides pins the accumulation order: one
    // closed-loop client sends the test split in order, so the router
    // executes one-row batches in row order, summing per-bank modeled
    // energy in ascending global bank id — exactly the single-process
    // session's order. Classes must match per input; the energy
    // roll-up must match to the last bit (any per-bank attribution
    // drift on any worker would perturb the f64 sum).
    for engine in EngineKind::ALL {
        if engine == EngineKind::Pjrt && !has_pjrt_artifacts() {
            eprintln!("skipping pjrt: run `make artifacts`");
            continue;
        }
        let c = spawn_cluster(engine, 1, 3, 0);
        let addr = c.router.local_addr().to_string();
        let mut client = Client::connect(&addr).unwrap();
        for (i, x) in c.inputs.iter().enumerate() {
            assert_eq!(
                client.classify(x).unwrap(),
                c.expected[i],
                "engine {} input {i}",
                engine.name()
            );
        }

        let snap = client.metrics().unwrap();
        assert_eq!(snap.decisions, c.inputs.len() as u64, "{}", engine.name());
        assert_eq!(snap.shed, 0);
        assert_eq!(snap.n_banks, 9);
        assert_eq!(
            snap.energy_per_dec.to_bits(),
            c.energy_per_dec.to_bits(),
            "modeled energy must be bit-identical: cluster {} vs single-process {} ({})",
            snap.energy_per_dec,
            c.energy_per_dec,
            engine.name()
        );

        // Per-worker attribution: the round-robin layout, every worker
        // alive, dispatched to, and reporting its own scraped roll-ups.
        assert_eq!(snap.per_worker.len(), 3, "{}", engine.name());
        for (w, wm) in snap.per_worker.iter().enumerate() {
            assert!(wm.alive, "worker {w} must be alive: {wm:?}");
            assert!(wm.dispatched > 0, "worker {w} never dispatched to");
            assert_eq!(wm.failed, 0);
            let banks: Vec<usize> = (0..9).filter(|b| b % 3 == w).collect();
            assert_eq!(wm.banks, banks, "worker {w} bank subset");
            let ws = wm.snapshot.as_ref().expect("scraped worker snapshot");
            assert!(ws.energy_per_dec > 0.0, "worker {w} energy attribution");
            assert_eq!(ws.n_banks, 3, "worker {w} serves 3 of the 9 banks");
        }

        let report = c.router.shutdown().unwrap();
        assert_eq!(report.metrics.decisions, c.inputs.len() as u64);
        assert_eq!(report.shed, 0);
        for w in c.workers {
            w.shutdown().unwrap();
        }
    }
}

#[test]
fn router_percentiles_derive_exactly_from_merged_worker_histograms() {
    // Drive latency samples into each worker's own front door (plain
    // `Request` frames work against a bank subset; only the samples
    // matter here, not the subset's classes), pool the scraped worker
    // histograms by hand, and the router's cluster-wide view must be
    // exactly that pooled histogram — its p99 equal to the pooled
    // histogram's percentile, which is by construction within one log2
    // bucket width of the true pooled sample p99. The old
    // decision-weighted percentile merge could not make this promise.
    // A second phase then drives traffic through the router alone —
    // the realistic pattern, where workers' request-plane histograms
    // stay empty — and the scraped percentiles must reflect the
    // router's own front-door samples, not collapse to zero.
    use dt2cam::obs::{bucket_index, bucket_upper, bucket_width, Histogram};

    let c = spawn_cluster(EngineKind::Native, 4, 3, 0);
    let per_worker = 20usize;
    for w in &c.workers {
        let mut client = Client::connect(&w.local_addr().to_string()).unwrap();
        for x in c.inputs.iter().take(per_worker) {
            let _ = client.classify(x).unwrap();
        }
    }

    let mut pooled = Histogram::new();
    for w in &c.workers {
        let snap = Client::connect(&w.local_addr().to_string())
            .unwrap()
            .metrics()
            .unwrap();
        assert_eq!(
            snap.latency_hist.count(),
            per_worker as u64,
            "every worker-side request must land in the worker's histogram"
        );
        pooled.merge(&snap.latency_hist);
    }
    assert_eq!(pooled.count(), (3 * per_worker) as u64);

    let addr = c.router.local_addr().to_string();
    let snap = Client::connect(&addr).unwrap().metrics().unwrap();
    // No traffic hit the router itself, so its merged histogram is
    // exactly the workers' pool (bucket-wise sum, no approximation)...
    assert_eq!(snap.latency_hist, pooled);
    // ...and the scraped percentiles come from that pool: identical to
    // the pooled histogram's own percentile read.
    let want_p99 = pooled.percentile(99.0);
    assert!(want_p99 > 0, "sampled latencies must be nonzero");
    assert_eq!((snap.latency_p99 * 1e9).round() as u64, want_p99);
    assert_eq!((snap.latency_p50 * 1e9).round() as u64, pooled.percentile(50.0));
    assert!(snap.latency_p50 <= snap.latency_p99);
    // The bucket-resolution contract the test banner promises: the
    // percentile read is a bucket upper bound, so it sits within one
    // bucket width of every sample in that bucket — including the true
    // pooled sample p99.
    let i = bucket_index(want_p99);
    assert_eq!(bucket_upper(i), want_p99);
    assert!(bucket_width(i) > 0);
    // The merged queue-delay mean is the pooled histogram's exact mean.
    assert!((snap.queue_delay_mean - snap.queue_hist.mean() * 1e-9).abs() < 1e-12);

    // Now the realistic traffic pattern: clients talk only to the
    // router. Workers see nothing but `BankBatch` frames — which record
    // no request-plane latency or queue samples — so the router's own
    // front-door histogram is the sole source of these figures, and the
    // merge must include it rather than discard it in favor of the
    // (empty) worker histograms.
    let per_router = 20usize;
    let mut client = Client::connect(&addr).unwrap();
    for x in c.inputs.iter().take(per_router) {
        let _ = client.classify(x).unwrap();
    }
    let snap = client.metrics().unwrap();
    assert_eq!(
        snap.latency_hist.count(),
        (3 * per_worker + per_router) as u64,
        "the router's own end-to-end samples must join the merged histogram"
    );
    assert!(
        snap.latency_p99 > 0.0,
        "router-only traffic must still yield a nonzero scraped tail latency"
    );
    // The scraped percentiles keep deriving from the (now combined)
    // histogram — the router's own samples included, exactly.
    assert_eq!(
        (snap.latency_p99 * 1e9).round() as u64,
        snap.latency_hist.percentile(99.0)
    );
    assert_eq!(
        (snap.latency_p50 * 1e9).round() as u64,
        snap.latency_hist.percentile(50.0)
    );
    assert!(
        snap.queue_hist.count() >= per_router as u64,
        "routed requests must contribute queue-delay samples"
    );

    c.router.shutdown().unwrap();
    for w in c.workers {
        w.shutdown().unwrap();
    }
}

#[test]
fn killing_a_replicated_worker_mid_load_loses_no_admitted_requests() {
    // replicas=1: every bank has two owners, so the fleet survives any
    // single death. Four concurrent clients hammer the router while
    // worker 0 is shut down mid-run — every admitted request must
    // still come back exactly once with the single-process class
    // (failover is allowed to cost latency, never answers).
    let mut c = spawn_cluster(EngineKind::Native, 8, 3, 1);
    let addr = c.router.local_addr().to_string();
    let n_clients = 4usize;
    let per_client = 50usize;
    let barrier = std::sync::Barrier::new(n_clients + 1);
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..n_clients)
            .map(|cix| {
                let addr = addr.clone();
                let inputs = &c.inputs;
                let expected = &c.expected;
                let barrier = &barrier;
                s.spawn(move || {
                    let mut client = Client::connect(&addr).unwrap();
                    barrier.wait();
                    for k in 0..per_client {
                        let i = (cix + k * n_clients) % inputs.len();
                        let got = client.classify(&inputs[i]).unwrap();
                        assert_eq!(got, expected[i], "client {cix} request {k} (input {i})");
                    }
                })
            })
            .collect();
        barrier.wait();
        // Let the load get going, then take out worker 0 (primary for
        // banks 0,3,6 — their replicas live on worker 1).
        std::thread::sleep(Duration::from_millis(25));
        c.workers.remove(0).shutdown().unwrap();
        for h in handles {
            h.join().unwrap();
        }
    });

    let mut probe = Client::connect(&addr).unwrap();
    let snap = probe.metrics().unwrap();
    assert_eq!(snap.decisions, (n_clients * per_client) as u64);
    assert_eq!(snap.shed, 0, "failover must not shed admitted requests");
    assert_eq!(snap.per_worker.len(), 3);

    c.router.shutdown().unwrap();
    for w in c.workers {
        w.shutdown().unwrap();
    }
}

#[test]
fn unreplicated_worker_death_answers_typed_errors_without_hanging() {
    // replicas=0: worker 0 is the only owner of banks 0,3,6. After it
    // dies every request needs an unserveable bank, so the router must
    // answer a typed error frame naming the bank — promptly (death is
    // detected on the broken socket, not by waiting out the 30 s reply
    // timeout) — and keep serving its control plane.
    let mut c = spawn_cluster(EngineKind::Native, 4, 3, 0);
    let addr = c.router.local_addr().to_string();
    let mut client = Client::connect(&addr).unwrap();
    assert_eq!(client.classify(&c.inputs[0]).unwrap(), c.expected[0]);

    c.workers.remove(0).shutdown().unwrap();

    let t0 = std::time::Instant::now();
    match client.classify(&c.inputs[1]) {
        Err(ClientError::Server { id, message }) => {
            assert!(id.is_some(), "the error must carry the request id");
            assert!(
                message.contains("unserveable"),
                "must name the failure, got: {message}"
            );
        }
        other => panic!("expected a typed server error, got {other:?}"),
    }
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "a dead sole owner must fail fast, not time out"
    );

    // The connection and the router both survive: the control plane
    // still answers, attributing the outage to worker 0.
    let snap = client.metrics().unwrap();
    assert_eq!(
        snap.per_worker.iter().filter(|w| w.alive).count(),
        2,
        "{:?}",
        snap.per_worker
    );
    let dead = &snap.per_worker[0];
    assert!(!dead.alive);
    assert!(dead.failed > 0, "the death must be accounted: {dead:?}");

    c.router.shutdown().unwrap();
    for w in c.workers {
        w.shutdown().unwrap();
    }
}
