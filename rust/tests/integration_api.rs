//! Integration tests for the typed pipeline facade (`dt2cam::api`):
//! backend parity across every registered `MatchBackend`, stage-artifact
//! JSON round-trips, and the two-process compile → serve flow.

use std::path::PathBuf;

use dt2cam::api::registry::{self, BackendOptions};
use dt2cam::api::serde::{lut_to_json, params_to_json};
use dt2cam::api::{
    CompiledProgram, DivisionMatches, DivisionRequest, Dt2Cam, MappedProgram, MatchBackend,
    RowMask,
};
use dt2cam::config::{EngineKind, Json};
use dt2cam::coordinator::Scheduler;
use dt2cam::tcam::params::DeviceParams;
use dt2cam::util::prng::Prng;

fn tmpfile(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("dt2cam_api_{name}_{}", std::process::id()))
}

/// The exact v1 (pre-bank) compiled-artifact writer layout,
/// reconstructed by hand: one top-level `lut`, no `banks` array.
fn v1_compiled_json(program: &CompiledProgram) -> Json {
    Json::obj(vec![
        ("format", Json::str("dt2cam-compiled-program")),
        ("version", Json::num(1.0)),
        ("dataset", Json::str(program.dataset.clone())),
        ("seed", Json::num(program.seed as f64)),
        ("lut", lut_to_json(program.lut())),
        (
            "test_indices",
            Json::Arr(program.test_indices.iter().map(|&i| Json::num(i as f64)).collect()),
        ),
        (
            "golden",
            Json::Arr(program.golden.iter().map(|&g| Json::num(g as f64)).collect()),
        ),
    ])
}

/// The exact v1 mapped-artifact writer layout: the single bank's fields
/// (map_seed, geometry, vref) at the top level.
fn v1_mapped_json(mapped: &MappedProgram) -> Json {
    let m = mapped.primary();
    Json::obj(vec![
        ("format", Json::str("dt2cam-mapped-program")),
        ("version", Json::num(1.0)),
        ("tile_size", Json::num(m.s as f64)),
        ("map_seed", Json::num(mapped.banks[0].map_seed as f64)),
        ("params", params_to_json(&mapped.params)),
        (
            "geometry",
            Json::obj(vec![
                ("n_rwd", Json::num(m.n_rwd as f64)),
                ("n_cwd", Json::num(m.n_cwd as f64)),
                ("padded_rows", Json::num(m.padded_rows as f64)),
                ("padded_width", Json::num(m.padded_width as f64)),
                ("real_rows", Json::num(m.real_rows as f64)),
                ("real_width", Json::num(m.real_width as f64)),
            ]),
        ),
        ("vref", Json::Arr(m.vref.iter().map(|&v| Json::num(v)).collect())),
        ("program", v1_compiled_json(&mapped.program)),
    ])
}

/// Build every registered backend; the pjrt entry skips cleanly when
/// `artifacts/manifest.json` is absent (offline checkout).
fn all_backends() -> Vec<Box<dyn MatchBackend>> {
    let opts = BackendOptions::default();
    let mut backends = Vec::new();
    for kind in EngineKind::ALL {
        if kind == EngineKind::Pjrt && !opts.artifacts_dir.join("manifest.json").exists() {
            eprintln!("skipping pjrt backend: run `make artifacts`");
            continue;
        }
        backends.push(registry::create(kind, &opts).unwrap());
    }
    backends
}

#[test]
fn every_registered_backend_produces_identical_decisions() {
    // THE seam-proving test: one batch, every backend, identical match
    // decisions and identical modeled energy accounting. haberman @16 is
    // multi-division and multi-row-tile, so selective precharge, mask
    // folding, and tile chunking are all exercised.
    let model = Dt2Cam::dataset("haberman").unwrap();
    let program = model.compile();
    let p = DeviceParams::default();
    let mapped = program.map(16, &p);
    let plan = mapped.plan();
    let sched = Scheduler::new(&plan, &p);

    let take = model.test_x.len().min(32);
    let queries: Vec<Vec<bool>> = model.test_x[..take]
        .iter()
        .map(|x| mapped.primary().pad_query(&program.lut().encode_input(x)))
        .collect();

    let backends = all_backends();
    assert!(backends.len() >= 2, "native + threaded-native always register");
    let baseline = sched
        .run_batch(backends[0].as_ref(), &queries, take)
        .unwrap();
    // Ideal hardware must match the software tree...
    for i in 0..take {
        assert_eq!(baseline.classes[i], Some(model.golden[i]), "lane {i}");
    }
    // ...and every other backend must match the baseline bit-for-bit.
    for backend in &backends[1..] {
        let out = sched.run_batch(backend.as_ref(), &queries, take).unwrap();
        assert_eq!(out.classes, baseline.classes, "backend {}", backend.name());
        assert_eq!(
            out.active_row_evals,
            baseline.active_row_evals,
            "backend {}",
            backend.name()
        );
        assert_eq!(
            out.modeled_energy,
            baseline.modeled_energy,
            "backend {}",
            backend.name()
        );
    }
}

#[test]
fn every_registered_backend_agrees_under_partial_masks() {
    // The disabled-row contract, registry-wide: under *partial* and
    // adversarial enable masks every backend must produce identical
    // packed match masks, with masked-off rows always false. The
    // full-mask parity test above cannot catch a backend that computes
    // real match bits for disabled rows (the pre-fix pjrt behavior) or
    // leaves them unset only on one of its dense/sparse paths.
    let model = Dt2Cam::dataset("haberman").unwrap();
    let program = model.compile();
    let p = DeviceParams::default();
    let mapped = program.map(16, &p);
    let plan = mapped.plan();

    let take = model.test_x.len().min(16);
    let queries: Vec<Vec<bool>> = model.test_x[..take]
        .iter()
        .map(|x| mapped.primary().pad_query(&program.lut().encode_input(x)))
        .collect();

    // Adversarial patterns over the padded rows: lane-staggered stripes,
    // single survivors with fully-gated lanes, the active prefix's tail
    // (tail-word stress), and rows *beyond* the initially-active prefix
    // (rogue/padding rows a scheduler would never enable).
    let patterns: Vec<(&str, Vec<RowMask>)> = vec![
        (
            "stripes",
            (0..take)
                .map(|lane| {
                    let mut m = RowMask::zeros(plan.padded_rows);
                    for r in (lane % 3..plan.padded_rows).step_by(3) {
                        m.set(r);
                    }
                    m
                })
                .collect(),
        ),
        (
            "single-survivor",
            (0..take)
                .map(|lane| {
                    let mut m = RowMask::zeros(plan.padded_rows);
                    if lane % 2 == 0 {
                        m.set(lane * 5 % plan.padded_rows);
                    }
                    m
                })
                .collect(),
        ),
        (
            "prefix-tail",
            (0..take)
                .map(|_| {
                    let mut m = RowMask::zeros(plan.padded_rows);
                    for r in plan.initially_active.saturating_sub(2)..plan.initially_active {
                        m.set(r);
                    }
                    m
                })
                .collect(),
        ),
        (
            "beyond-prefix",
            (0..take)
                .map(|_| {
                    let mut m = RowMask::zeros(plan.padded_rows);
                    for r in plan.initially_active..plan.padded_rows {
                        m.set(r);
                    }
                    m
                })
                .collect(),
        ),
    ];

    let backends = all_backends();
    for (name, enabled) in &patterns {
        for d in 0..plan.n_cwd {
            let req = DivisionRequest {
                division: d,
                queries: &queries,
                enabled,
            };
            let mut baseline = DivisionMatches::new();
            backends[0].match_division(&plan, &req, &mut baseline).unwrap();
            // Normative: no backend may report a disabled row as matched.
            for (lane, m) in baseline.iter().enumerate() {
                for row in m.ones() {
                    assert!(
                        enabled[lane].get(row),
                        "{name}: disabled row {row} set (lane {lane}, div {d})"
                    );
                }
            }
            for backend in &backends[1..] {
                let mut got = DivisionMatches::new();
                backend.match_division(&plan, &req, &mut got).unwrap();
                assert_eq!(
                    got,
                    baseline,
                    "backend {} diverges on pattern '{name}', division {d}",
                    backend.name()
                );
            }
        }
    }
}

#[test]
fn compiled_program_roundtrips_through_file() {
    let program = Dt2Cam::dataset("iris").unwrap().compile();
    let path = tmpfile("compiled.json");
    program.save(&path).unwrap();
    let back = CompiledProgram::load(&path).unwrap();
    std::fs::remove_file(&path).ok();

    assert_eq!(back.dataset, program.dataset);
    assert_eq!(back.seed, program.seed);
    assert_eq!(back.lut().stored, program.lut().stored);
    assert_eq!(back.lut().classes, program.lut().classes);
    assert_eq!(back.lut().encoders, program.lut().encoders);
    assert_eq!(back.test_indices, program.test_indices);
    assert_eq!(back.golden, program.golden);

    // Behavioral equivalence: the reloaded program classifies like the
    // original on the real test split.
    let (test_x, _) = back.test_split().unwrap();
    for x in test_x.iter().take(15) {
        assert_eq!(back.classify(x), program.classify(x));
    }
}

#[test]
fn mapped_program_roundtrips_through_file() {
    let program = Dt2Cam::dataset("haberman").unwrap().compile();
    let p = DeviceParams::default();
    let mut mapped = program.map(16, &p);
    // Carry a vref perturbation through the artifact (variability
    // workflows re-serve perturbed plans).
    mapped.banks[0].mapped.vref[7] += 0.011;

    let path = tmpfile("mapped.json");
    mapped.save(&path).unwrap();
    let back = MappedProgram::load(&path).unwrap();
    std::fs::remove_file(&path).ok();

    assert_eq!(back.tile_size(), 16);
    assert_eq!(back.banks[0].map_seed, mapped.banks[0].map_seed);
    assert_eq!(back.primary().cells, mapped.primary().cells);
    assert_eq!(back.primary().classes, mapped.primary().classes);
    assert_eq!(back.primary().vref, mapped.primary().vref);
    assert_eq!(back.params.r_lrs, mapped.params.r_lrs);

    // The rebuilt plan serves identically.
    let sched_plan = back.plan();
    let orig_plan = mapped.plan();
    assert_eq!(sched_plan.n_rwd, orig_plan.n_rwd);
    assert_eq!(sched_plan.n_cwd, orig_plan.n_cwd);
    for (a, b) in sched_plan.divisions.iter().zip(&orig_plan.divisions) {
        assert_eq!(a.w, b.w);
        assert_eq!(a.vref, b.vref);
    }
}

#[test]
fn two_process_compile_then_serve_via_artifact() {
    // Process 1: compile + map + save.
    let path = tmpfile("two_process.json");
    {
        let program = Dt2Cam::dataset("iris").unwrap().compile();
        program.map(16, &DeviceParams::default()).save(&path).unwrap();
    }

    // Process 2: load the artifact cold (no TrainedModel in scope), build
    // a session, and serve the test split re-derived from the artifact.
    let mapped = MappedProgram::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let (test_x, _test_y) = mapped.program.test_split().unwrap();
    let mut session = mapped.session(EngineKind::Native, 8).unwrap();
    let classes = session.classify_all(&test_x).unwrap();
    assert_eq!(classes.len(), mapped.program.golden.len());
    for (c, g) in classes.iter().zip(&mapped.program.golden) {
        assert_eq!(*c, Some(*g), "artifact-served class must match golden");
    }
    assert!(session.metrics().energy_per_dec() > 0.0);
}

#[test]
fn sessions_agree_across_registered_engines() {
    let model = Dt2Cam::dataset("iris").unwrap();
    let mapped = model.compile().map(16, &DeviceParams::default());
    let native = mapped
        .session(EngineKind::Native, 8)
        .unwrap()
        .classify_all(&model.test_x)
        .unwrap();
    let threaded = mapped
        .session(EngineKind::ThreadedNative, 8)
        .unwrap()
        .classify_all(&model.test_x)
        .unwrap();
    assert_eq!(native, threaded);
    if std::path::Path::new("artifacts/manifest.json").exists() {
        let pjrt = mapped
            .session(EngineKind::Pjrt, 8)
            .unwrap()
            .classify_all(&model.test_x)
            .unwrap();
        assert_eq!(native, pjrt);
    }
}

#[test]
fn forest_program_backend_parity_and_votes() {
    // The multi-bank seam-proving test: a 3-bank forest program, every
    // registered backend. Per-bank match outcomes must be bit-identical
    // (classes, energy, row activity) and the sessions' final majority
    // votes must agree across engines — with the usual clean pjrt skip.
    use dt2cam::cart::ForestParams;
    use dt2cam::coordinator::ServingPlan;

    let fp = ForestParams {
        n_trees: 3,
        sample_fraction: 0.8,
        max_features: 2,
        ..Default::default()
    };
    let model = Dt2Cam::forest("haberman", &fp).unwrap();
    let program = model.compile();
    let p = DeviceParams::default();
    let mapped = program.map(16, &p);
    assert_eq!(mapped.n_banks(), 3);

    let take = model.test_x.len().min(16);
    let backends = all_backends();
    assert!(backends.len() >= 2);
    for (bi, mb) in mapped.banks.iter().enumerate() {
        let lut = &program.banks[bi].lut;
        let feats = &program.banks[bi].features;
        let plan = ServingPlan::build_bank(&mb.mapped, &mb.mapped.vref, &p, bi);
        let sched = Scheduler::new(&plan, &p);
        let queries: Vec<Vec<bool>> = model.test_x[..take]
            .iter()
            .map(|x| {
                let proj: Vec<f64> = feats.iter().map(|&f| x[f]).collect();
                mb.mapped.pad_query(&lut.encode_input(&proj))
            })
            .collect();
        let base = sched.run_batch(backends[0].as_ref(), &queries, take).unwrap();
        assert_eq!(base.bank, bi, "outcome must carry its bank id");
        for backend in &backends[1..] {
            let out = sched.run_batch(backend.as_ref(), &queries, take).unwrap();
            assert_eq!(out.classes, base.classes, "bank {bi}, backend {}", backend.name());
            assert_eq!(out.active_row_evals, base.active_row_evals, "bank {bi}");
            assert_eq!(out.modeled_energy, base.modeled_energy, "bank {bi}");
        }
    }

    // Session-level: final votes bit-identical across engines and equal
    // to the software forest (ideal hardware).
    let opts = BackendOptions::default();
    let mut per_engine: Vec<(&str, Vec<Option<usize>>)> = Vec::new();
    for kind in EngineKind::ALL {
        if kind == EngineKind::Pjrt && !opts.artifacts_dir.join("manifest.json").exists() {
            eprintln!("skipping pjrt session: run `make artifacts`");
            continue;
        }
        let mut session = mapped.session(kind, 8).unwrap();
        assert_eq!(session.n_banks(), 3);
        per_engine.push((kind.name(), session.classify_all(&model.test_x).unwrap()));
    }
    for (c, g) in per_engine[0].1.iter().zip(&model.golden) {
        assert_eq!(*c, Some(*g), "ideal hardware must match the software forest");
    }
    for (name, votes) in &per_engine[1..] {
        assert_eq!(votes, &per_engine[0].1, "engine {name} votes diverge");
    }
}

#[test]
fn v1_compiled_artifact_loads_as_one_bank_v2_program() {
    // Back-compat: a pre-bank (v1) compiled artifact — single top-level
    // `lut`, no `banks` array — must load as a 1-bank v2 program with
    // the identity feature projection and identical classifications.
    let model = Dt2Cam::dataset("iris").unwrap();
    let program = model.compile();
    let v1 = v1_compiled_json(&program);
    let path = tmpfile("v1_compiled.json");
    std::fs::write(&path, v1.to_string_pretty()).unwrap();
    let back = CompiledProgram::load(&path).unwrap();
    std::fs::remove_file(&path).ok();

    assert_eq!(back.n_banks(), 1);
    assert_eq!(
        back.banks[0].features,
        (0..program.lut().encoders.len()).collect::<Vec<_>>(),
        "v1 upgrade must use the identity projection"
    );
    assert_eq!(back.lut().stored, program.lut().stored);
    for x in &model.test_x {
        assert_eq!(back.classify(x), program.classify(x));
    }
    // And the upgraded program re-saves as v2, round-tripping cleanly.
    let text = back.to_json().to_string_pretty();
    let again = CompiledProgram::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(again.lut().stored, back.lut().stored);
}

#[test]
fn v1_mapped_artifact_loads_and_classifies_identically() {
    // Back-compat at the mapped level: a v1 artifact (bank fields at the
    // top level) loads as a 1-bank v2 program whose grid, vref and
    // served classifications are identical to the v2 mapping of the
    // same program.
    let model = Dt2Cam::dataset("haberman").unwrap();
    let program = model.compile();
    let p = DeviceParams::default();
    let mapped = program.map(16, &p);
    let m = mapped.primary();

    let v1 = v1_mapped_json(&mapped);
    let path = tmpfile("v1_mapped.json");
    std::fs::write(&path, v1.to_string_pretty()).unwrap();
    let back = MappedProgram::load(&path).unwrap();
    std::fs::remove_file(&path).ok();

    assert_eq!(back.n_banks(), 1);
    assert_eq!(back.tile_size(), 16);
    assert_eq!(back.banks[0].map_seed, mapped.banks[0].map_seed);
    assert_eq!(back.primary().cells, m.cells, "v1 grid must rebuild bit-identically");
    assert_eq!(back.primary().vref, m.vref);

    // Serving the v1-loaded program gives the same classifications as
    // the v2 program (and the golden tree).
    let a = back
        .session(EngineKind::Native, 8)
        .unwrap()
        .classify_all(&model.test_x)
        .unwrap();
    let b = mapped
        .session(EngineKind::Native, 8)
        .unwrap()
        .classify_all(&model.test_x)
        .unwrap();
    assert_eq!(a, b);
    for (c, g) in a.iter().zip(&model.golden) {
        assert_eq!(*c, Some(*g));
    }
}

// -------------------------------------------------- artifact robustness

fn count_nodes(j: &Json) -> usize {
    1 + match j {
        Json::Obj(fields) => fields.iter().map(|(_, v)| count_nodes(v)).sum(),
        Json::Arr(items) => items.iter().map(count_nodes).sum(),
        _ => 0,
    }
}

/// Replace the pre-order `target`-th node of the tree with `with`.
fn replace_node(j: &mut Json, cursor: &mut usize, target: usize, with: &Json) -> bool {
    if *cursor == target {
        *j = with.clone();
        return true;
    }
    *cursor += 1;
    match j {
        Json::Obj(fields) => fields
            .iter_mut()
            .any(|(_, v)| replace_node(v, cursor, target, with)),
        Json::Arr(items) => items
            .iter_mut()
            .any(|v| replace_node(v, cursor, target, with)),
        _ => false,
    }
}

#[test]
fn mutated_artifacts_fail_loudly_naming_the_path_never_panic() {
    // The robustness property over all four artifact flavors (v1/v2 ×
    // compiled/mapped): under a seeded stream of corruptions —
    // truncation at arbitrary offsets, single-byte damage, and
    // wrong-typed node replacements anywhere in the JSON tree — `load`
    // either succeeds (the mutation happened to be benign) or returns a
    // typed error that names the artifact path. It must **never**
    // panic: every mutated byte stream runs through the full
    // parse → validate → rebuild path in-process right here.
    let program = Dt2Cam::dataset("iris").unwrap().compile();
    let mapped = program.map(16, &DeviceParams::default());
    let cases: Vec<(&str, String, bool)> = vec![
        ("v2c", program.to_json().to_string_pretty(), false),
        ("v2m", mapped.to_json().to_string_pretty(), true),
        ("v1c", v1_compiled_json(&program).to_string_pretty(), false),
        ("v1m", v1_mapped_json(&mapped).to_string_pretty(), true),
    ];
    let wrong_typed = [
        Json::str("bogus"),
        Json::num(-7.0),
        Json::num(2.5),
        Json::Null,
        Json::Arr(vec![]),
        Json::obj(vec![]),
    ];
    let mut rng = Prng::new(0xC0FFEE);
    for (tag, text, is_mapped) in &cases {
        assert!(text.is_ascii(), "byte-offset mutations assume ASCII artifacts");
        for k in 0..15usize {
            let mutated = match k % 3 {
                // Truncation at a seeded offset (the "process died
                // mid-write" artifact).
                0 => text[..1 + rng.below(text.len() - 1)].to_string(),
                // One corrupted byte (bit-rot; may or may not stay
                // parseable).
                1 => {
                    let mut bytes = text.clone().into_bytes();
                    bytes[rng.below(bytes.len())] = b'#';
                    String::from_utf8(bytes).unwrap()
                }
                // A wrong-typed value at a seeded node of the tree.
                _ => {
                    let mut j = Json::parse(text).unwrap();
                    let target = rng.below(count_nodes(&j));
                    let with = &wrong_typed[rng.below(wrong_typed.len())];
                    let mut cursor = 0usize;
                    replace_node(&mut j, &mut cursor, target, with);
                    j.to_string_pretty()
                }
            };
            let path = tmpfile(&format!("mut_{tag}_{k}"));
            std::fs::write(&path, &mutated).unwrap();
            let err = if *is_mapped {
                MappedProgram::load(&path).err().map(|e| format!("{e:#}"))
            } else {
                CompiledProgram::load(&path).err().map(|e| format!("{e:#}"))
            };
            std::fs::remove_file(&path).ok();
            if let Some(msg) = err {
                assert!(
                    msg.contains(&path.display().to_string()),
                    "{tag} mutation {k}: error must name the artifact path: {msg}"
                );
            }
        }
    }
}

#[test]
fn truncated_and_wrong_typed_artifacts_error_deterministically() {
    // The targeted (non-random) corners of the robustness property,
    // pinned so a regression names itself: hard truncation, a
    // wrong-typed version, and a mapped artifact whose tile size was
    // damaged to something the grid rebuild would have panicked on.
    let program = Dt2Cam::dataset("iris").unwrap().compile();
    let mapped = program.map(16, &DeviceParams::default());

    // Truncated mid-stream: a parse error naming the path.
    let text = mapped.to_json().to_string_pretty();
    let path = tmpfile("truncated_mapped.json");
    std::fs::write(&path, &text[..text.len() / 2]).unwrap();
    let msg = format!("{:#}", MappedProgram::load(&path).unwrap_err());
    std::fs::remove_file(&path).ok();
    assert!(msg.contains(&path.display().to_string()), "{msg}");

    // Wrong-typed version field.
    let bad = text.replace("\"version\": 2", "\"version\": \"two\"");
    let err = MappedProgram::from_json(&Json::parse(&bad).unwrap()).unwrap_err();
    assert!(format!("{err:#}").contains("version"), "{err:#}");

    // Zero tile size (used to reach a divide-by-zero in the grid
    // rebuild): typed error naming the field.
    let bad = text.replace("\"tile_size\": 16", "\"tile_size\": 0");
    let err = MappedProgram::from_json(&Json::parse(&bad).unwrap()).unwrap_err();
    assert!(format!("{err:#}").contains("tile size"), "{err:#}");
}

#[test]
fn corrupted_artifact_fails_loudly() {
    let program = Dt2Cam::dataset("iris").unwrap().compile();
    let mut j = program.map(16, &DeviceParams::default()).to_json();
    // Flip bank 0's stored geometry: load must detect the mismatch.
    if let Json::Obj(fields) = &mut j {
        for (k, v) in fields.iter_mut() {
            if k == "banks" {
                if let Json::Arr(banks) = v {
                    if let Json::Obj(bank) = &mut banks[0] {
                        for (bk, bv) in bank.iter_mut() {
                            if bk == "geometry" {
                                if let Json::Obj(geo) = bv {
                                    for (gk, gv) in geo.iter_mut() {
                                        if gk == "padded_rows" {
                                            *gv = Json::num(9999.0);
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
    }
    let err = MappedProgram::from_json(&j).unwrap_err();
    assert!(format!("{err:#}").contains("geometry"));
}
