//! Bench/regeneration target for paper Table IV: D_cap limit → max cells
//! per row → chosen tile size S (Eqn 6 sweep).

use dt2cam::synth::range::table4;
use dt2cam::tcam::params::DeviceParams;
use dt2cam::util::benchkit::Bench;

fn main() {
    let p = DeviceParams::default();
    let mut b = Bench::new("table4_dynamic_range");

    // Regenerate the table (paper values in brackets for eyeballing):
    // 0.2→154/128, 0.3→86/64, 0.4→53/32, 0.5→33/32, 0.6→21/16.
    let rows = table4(&p);
    b.report_line("D_limit  max#cells  chosen_S  D(S)      [paper: 154/128, 86/64, 53/32, 33/32, 21/16]");
    for r in &rows {
        b.report_line(&format!(
            "{:<8.1} {:>9} {:>9}  {:.3}",
            r.d_limit, r.max_cells, r.chosen_s, r.d_at_chosen
        ));
    }
    assert_eq!(
        rows.iter().map(|r| r.chosen_s).collect::<Vec<_>>(),
        vec![128, 64, 32, 32, 16],
        "Table IV S column must match the paper exactly"
    );

    b.case("table4_full_sweep", || {
        std::hint::black_box(table4(&p));
    });
    b.case("dynamic_range_eqn6_at_128", || {
        std::hint::black_box(p.dynamic_range(128));
    });
    b.case("t_opt_eqn8_at_128", || {
        std::hint::black_box(p.t_opt(128));
    });
    b.finish();
}
