//! Bench/regeneration target for paper Fig 8: % accuracy loss versus the
//! number of TCAM tiles the dataset needs, under stuck-at-fault sweeps.

use dt2cam::report::figures::{fig8, render_fig8};
use dt2cam::report::workload::Workload;
use dt2cam::tcam::params::DeviceParams;
use dt2cam::util::benchkit::Bench;

fn main() {
    let full = std::env::var("DT2CAM_BENCH_FULL").is_ok();
    let mut names = vec![
        "iris", "haberman", "cancer", "diabetes", "titanic", "car", "covid",
    ];
    if full {
        names.push("credit");
    }
    let p = DeviceParams::default();
    let mut b = Bench::new("fig8_tiles_acc");

    let mut workloads = Vec::new();
    for n in &names {
        workloads.push(Workload::prepare(n).unwrap());
    }
    let wrefs: Vec<&Workload> = workloads.iter().collect();
    let trials = if full { 3 } else { 1 };
    let pts = fig8(&wrefs, &p, &[0.0, 0.1, 0.5], trials);
    for line in render_fig8(&pts).lines() {
        b.report_line(line);
    }
    b.report_line("[paper trend: loss grows with SAF rate; more-tile configs expose more devices]");

    // Zero-SAF points must be exactly zero loss (ideal hardware).
    for q in pts.iter().filter(|q| q.saf_pct == 0.0) {
        assert!(
            q.acc_loss_pp.abs() < 1e-9,
            "{} S={} lost accuracy with no faults",
            q.dataset,
            q.s
        );
    }

    let iris = Workload::prepare("iris").unwrap();
    b.case("fig8_iris_sweep", || {
        std::hint::black_box(fig8(&[&iris], &p, &[0.0, 0.5], 1));
    });
    b.finish();
}
