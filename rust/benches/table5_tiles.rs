//! Bench/regeneration target for paper Table V: LUT sizes and tile grids
//! per dataset per S. Includes the full compile pipeline timing.
//!
//! Default runs the seven light datasets; set DT2CAM_BENCH_FULL=1 to also
//! build Credit (120k instances, ~4 s of CART training).

use dt2cam::report::tables::{render_table5, table5};
use dt2cam::report::workload::Workload;
use dt2cam::util::benchkit::Bench;

fn main() {
    let full = std::env::var("DT2CAM_BENCH_FULL").is_ok();
    let mut names = vec![
        "iris", "diabetes", "haberman", "car", "cancer", "titanic", "covid",
    ];
    if full {
        names.push("credit");
    }

    let mut b = Bench::new("table5_tiles");
    let mut workloads = Vec::new();
    for n in &names {
        workloads.push(Workload::prepare(n).unwrap());
    }
    let wrefs: Vec<&Workload> = workloads.iter().collect();
    let rows = table5(&wrefs);
    for line in render_table5(&rows).lines() {
        b.report_line(line);
    }
    b.report_line("[paper: iris 9x12, diabetes 120x123, haberman 93x71, car 76x20,");
    b.report_line("        cancer 23x52, credit 8475x3580, titanic 191x150, covid 441x146]");

    // Tile-grid formula itself is what Table V reports; time the full
    // train→parse→reduce→encode pipeline per dataset class.
    b.case("prepare_workload_iris", || {
        std::hint::black_box(Workload::prepare("iris").unwrap());
    });
    b.case("prepare_workload_haberman", || {
        std::hint::black_box(Workload::prepare("haberman").unwrap());
    });
    b.case("table5_assembly", || {
        std::hint::black_box(table5(&wrefs));
    });
    b.finish();
}
