//! Ablation: the paper's *adaptive-precision* ternary encoding vs a
//! fixed-precision baseline (every feature padded to the widest field).
//! Quantifies the compactness claim behind §II.A.4 / Eqns 1–2.

use dt2cam::report::workload::Workload;
use dt2cam::util::benchkit::Bench;

fn main() {
    let mut b = Bench::new("ablation_encoding");
    b.report_line("dataset     adaptive_bits  fixed_bits   savings%    rows  width");
    for name in [
        "iris", "diabetes", "haberman", "car", "cancer", "titanic", "covid",
    ] {
        let w = Workload::prepare(name).unwrap();
        let adaptive = w.lut.n_total();
        let fixed = w.lut.fixed_precision_total_bits();
        let savings = 100.0 * (1.0 - adaptive as f64 / fixed as f64);
        b.report_line(&format!(
            "{name:<11} {adaptive:>13} {fixed:>11} {savings:>9.1} {:>7} {:>6}",
            w.lut.n_rows(),
            w.lut.width()
        ));
        assert!(
            adaptive <= fixed,
            "{name}: adaptive encoding must never be wider"
        );
    }

    let w = Workload::prepare("haberman").unwrap();
    b.case("compile_lut_haberman", || {
        std::hint::black_box(dt2cam::compiler::compile(&w.tree));
    });
    b.finish();
}
