//! Extension bench: random-forest ensembles on ReCAM banks (the workload
//! class of the paper's comparators [15]/[20]). Each tree compiles to its
//! own LUT bank; banks search in parallel and a digital majority vote
//! combines them. Reports the ensemble's accuracy / hardware-cost curve
//! against the single unpruned tree.

use dt2cam::cart::{train_forest, ForestParams, TrainParams};
use dt2cam::compiler::compile;
use dt2cam::dataset::catalog;
use dt2cam::synth::mapping::MappedArray;
use dt2cam::synth::simulate::{simulate, SimOptions};
use dt2cam::tcam::params::DeviceParams;
use dt2cam::util::benchkit::Bench;
use dt2cam::util::prng::Prng;

fn main() {
    let p = DeviceParams::default();
    let mut b = Bench::new("ablation_forest");
    b.report_line("dataset    trees  depth  acc      total-leaves  total-tiles  sum nJ/dec");

    for name in ["diabetes", "titanic"] {
        let mut d = catalog::by_name(name, 0xD72CA0).unwrap();
        d.normalize();
        let mut rng = Prng::new(11);
        let split = d.split(0.9, &mut rng);
        let (xs, ys) = d.gather(&split.train);
        let (txs, tys) = d.gather(&split.test);

        for (n_trees, depth) in [(1usize, 0usize), (5, 6), (9, 6), (15, 4)] {
            let fp = ForestParams {
                n_trees,
                sample_fraction: 0.8,
                max_features: (d.n_features() as f64).sqrt().ceil() as usize,
                tree: TrainParams {
                    max_depth: depth,
                    ..TrainParams::default()
                },
            };
            let forest = train_forest(&xs, &ys, d.n_classes, &fp, &mut rng);

            // Per-bank hardware cost + per-bank CAM classification.
            let mut total_tiles = 0usize;
            let mut total_energy = 0.0f64;
            let mut per_tree_cls: Vec<Vec<usize>> = Vec::new();
            for (tree, feats) in forest.trees.iter().zip(&forest.feature_sets) {
                let lut = compile(tree);
                let m = MappedArray::from_lut(&lut, 64, &p, &mut rng);
                let ptx: Vec<Vec<f64>> = txs
                    .iter()
                    .map(|x| feats.iter().map(|&f| x[f]).collect())
                    .collect();
                let golden: Vec<usize> = ptx.iter().map(|x| tree.predict(x)).collect();
                let r = simulate(
                    &m, &lut, &ptx, &tys, &golden, &m.vref, &p,
                    &SimOptions { max_inputs: 256, ..Default::default() },
                );
                assert_eq!(r.golden_agreement, 1.0, "{name}: bank must match its tree");
                total_tiles += m.n_tiles();
                total_energy += r.energy_per_dec;
                per_tree_cls.push(golden);
            }
            // Majority vote over the banks' surviving-row classes.
            let n_eval = txs.len().min(256);
            let correct = (0..n_eval)
                .filter(|&i| {
                    let votes: Vec<usize> =
                        per_tree_cls.iter().map(|c| c[i]).collect();
                    forest.vote(&votes) == tys[i]
                })
                .count();
            let acc = correct as f64 / n_eval as f64;
            b.report_line(&format!(
                "{name:<10} {n_trees:>5} {:>6} {acc:>8.4} {:>13} {:>12} {:>11.4}",
                if depth == 0 { "inf".into() } else { depth.to_string() },
                forest.total_leaves(),
                total_tiles,
                total_energy * 1e9,
            ));
        }
    }
    b.report_line("[ensembles of shallow trees reach single-tree accuracy with bounded");
    b.report_line(" per-bank LUTs; banks are independent CAMs searching in parallel]");

    let mut d = catalog::by_name("haberman", 1).unwrap();
    d.normalize();
    let fp = ForestParams {
        n_trees: 5,
        tree: TrainParams { max_depth: 4, ..Default::default() },
        ..Default::default()
    };
    let mut rng = Prng::new(3);
    b.case("train_forest_5x_haberman", || {
        std::hint::black_box(train_forest(&d.features, &d.labels, d.n_classes, &fp, &mut rng));
    });
    b.finish();
}
