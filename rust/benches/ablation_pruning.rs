//! Ablation: tree complexity vs hardware cost — the design-space knob the
//! paper's pipeline implies but does not sweep. Limiting CART depth trades
//! recognition accuracy against LUT rows/width, tiles, energy and EDP; the
//! knee tells a deployer how much array to provision.

use dt2cam::cart::{train, TrainParams};
use dt2cam::compiler::compile;
use dt2cam::dataset::catalog;
use dt2cam::synth::mapping::MappedArray;
use dt2cam::synth::simulate::{simulate, SimOptions};
use dt2cam::tcam::params::DeviceParams;
use dt2cam::util::benchkit::Bench;
use dt2cam::util::prng::Prng;

fn main() {
    let p = DeviceParams::default();
    let mut b = Bench::new("ablation_pruning");
    b.report_line("dataset    depth  leaves  LUT WxR      tiles  acc     nJ/dec    EDP(J.s)");

    for name in ["diabetes", "covid"] {
        let mut d = catalog::by_name(name, 0xD72CA0).unwrap();
        d.normalize();
        let mut rng = Prng::new(7);
        let split = d.split(0.9, &mut rng);
        let (xs, ys) = d.gather(&split.train);
        let (txs, tys) = d.gather(&split.test);

        let mut prev_acc = 0.0f64;
        for depth in [2usize, 4, 6, 8, 0] {
            let params = TrainParams {
                max_depth: depth,
                ..TrainParams::default()
            };
            let tree = train(&xs, &ys, d.n_classes, &params);
            let lut = compile(&tree);
            let golden: Vec<usize> = txs.iter().map(|x| tree.predict(x)).collect();
            let m = MappedArray::from_lut(&lut, 64, &p, &mut rng);
            let r = simulate(
                &m, &lut, &txs, &tys, &golden, &m.vref, &p,
                &SimOptions { max_inputs: 512, ..Default::default() },
            );
            b.report_line(&format!(
                "{name:<10} {:>5} {:>7} {:>5}x{:<6} {:>5} {:>7.4} {:>9.4} {:>9.3e}",
                if depth == 0 { "inf".to_string() } else { depth.to_string() },
                tree.n_leaves(),
                lut.n_rows(),
                lut.width(),
                m.n_tiles(),
                r.accuracy,
                r.energy_per_dec * 1e9,
                r.edp,
            ));
            // Ideal hardware always equals this tree's own predictions.
            assert_eq!(r.golden_agreement, 1.0, "{name} depth {depth}");
            // Deeper trees cost more hardware.
            if depth == 0 {
                assert!(
                    r.accuracy + 0.02 >= prev_acc,
                    "{name}: unpruned should be at least as accurate as depth-8"
                );
            }
            prev_acc = r.accuracy;
        }
    }
    b.report_line("[knee: most of the accuracy arrives by depth ~6 at a fraction of the tiles]");

    let mut d = catalog::by_name("haberman", 1).unwrap();
    d.normalize();
    let shallow = TrainParams {
        max_depth: 4,
        ..TrainParams::default()
    };
    b.case("train_depth4_haberman", || {
        std::hint::black_box(train(&d.features, &d.labels, d.n_classes, &shallow));
    });
    b.finish();
}
