//! Bench/regeneration target for paper Fig 6 (a: energy vs throughput,
//! b: EDP, c: % EDP reduction from selective precharge), per dataset per
//! tile size.
//!
//! Default covers the seven light datasets; DT2CAM_BENCH_FULL=1 adds
//! Credit (the paper's biggest point — highest energy, lowest throughput,
//! ~90% SP reduction).

use dt2cam::report::figures::{fig6, render_fig6};
use dt2cam::report::workload::Workload;
use dt2cam::tcam::params::DeviceParams;
use dt2cam::util::benchkit::Bench;

fn main() {
    let full = std::env::var("DT2CAM_BENCH_FULL").is_ok();
    let mut names = vec![
        "iris", "diabetes", "haberman", "car", "cancer", "titanic", "covid",
    ];
    if full {
        names.push("credit");
    }
    let p = DeviceParams::default();
    let mut b = Bench::new("fig6_energy_throughput");

    let mut all = Vec::new();
    for n in &names {
        let w = Workload::prepare(n).unwrap();
        all.extend(fig6(&w, &p));
    }
    for line in render_fig6(&all).lines() {
        b.report_line(line);
    }
    b.report_line("[paper trends: energy/throughput grow with dataset size; EDP improves");
    b.report_line(" with S for all but Iris; SP reduces EDP wherever N_cwd > 1, up to ~90% (Credit)]");

    // Trend assertions (the reproduction's 'shape' checks).
    let covid16 = all
        .iter()
        .find(|q| q.dataset == "covid" && q.s == 16)
        .unwrap();
    let covid128 = all
        .iter()
        .find(|q| q.dataset == "covid" && q.s == 128)
        .unwrap();
    assert!(
        covid128.throughput > covid16.throughput,
        "covid throughput must improve with S"
    );
    assert!(covid128.edp < covid16.edp, "covid EDP must improve with S");
    let iris = all
        .iter()
        .find(|q| q.dataset == "iris" && q.s == 16)
        .unwrap();
    assert!(
        covid16.energy_nj > iris.energy_nj,
        "bigger dataset must burn more energy/dec"
    );

    let w = Workload::prepare("haberman").unwrap();
    b.case("fig6_haberman_full_sweep", || {
        std::hint::black_box(fig6(&w, &p));
    });
    b.finish();
}
