//! Bench/regeneration target for paper Fig 9: energy vs throughput
//! scatter of DT2CAM against the SOTA accelerators.

use dt2cam::report::figures::{fig9, render_fig9};
use dt2cam::tcam::params::DeviceParams;
use dt2cam::util::benchkit::Bench;

fn main() {
    let p = DeviceParams::default();
    let mut b = Bench::new("fig9_sota_scatter");
    let rows = fig9(&p);
    for line in render_fig9(&rows).lines() {
        b.report_line(line);
    }
    b.report_line("[paper: DT2CAM sits in the lowest-energy / highest-throughput corner]");

    // DT2CAM must dominate on both axes among the 16nm CAM designs.
    let ours = rows.iter().find(|r| r.name == "DT2CAM_128").unwrap();
    let acam = rows.iter().find(|r| r.name == "ACAM [15]").unwrap();
    assert!(ours.energy_per_dec < acam.energy_per_dec);
    assert!(ours.throughput > acam.throughput);

    b.case("fig9_assembly", || {
        std::hint::black_box(fig9(&p));
    });
    b.finish();
}
