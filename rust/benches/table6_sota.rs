//! Bench/regeneration target for paper Table VI: the SOTA comparison on
//! the traffic configuration (2000×2048 @ S=128), sequential + pipelined,
//! with the FOM column (Eqn 12).

use dt2cam::report::sota::{dt2cam_traffic_rows, fom};
use dt2cam::report::tables::{render_table6, table6};
use dt2cam::tcam::params::DeviceParams;
use dt2cam::util::benchkit::Bench;

fn main() {
    let p = DeviceParams::default();
    let mut b = Bench::new("table6_sota");

    let rows = table6(&p);
    for line in render_table6(&rows).lines() {
        b.report_line(line);
    }
    b.report_line("[paper DT2CAM_128: 58.8e6 dec/s, 0.098 nJ, 0.07 mm2, 0.017 um2/bit, FOM 1.22e-19]");
    b.report_line("[paper P-DT2CAM_128: 333e6 dec/s, FOM 2.15e-20]");

    // Headline ratios from §IV.C.
    let ours = dt2cam_traffic_rows(&p);
    let acam_e = 0.17e-9;
    b.report_value(
        "energy_ratio_vs_ACAM (paper 1.73x)",
        acam_e / ours[0].energy_per_dec,
        "x",
    );
    b.report_value(
        "area_ratio_vs_ACAM (paper 3.8x)",
        0.266 / ours[0].area_mm2.unwrap(),
        "x",
    );
    let fom_acam = fom(acam_e, 20.8e6, 0.266);
    let fom_ours = fom(
        ours[0].energy_per_dec,
        ours[0].throughput,
        ours[0].area_mm2.unwrap(),
    );
    b.report_value("FOM_ratio_seq (paper 17.8x)", fom_acam / fom_ours, "x");
    let fom_pacam = fom(acam_e, 333e6, 0.266);
    let fom_p = fom(
        ours[1].energy_per_dec,
        ours[1].throughput,
        ours[1].area_mm2.unwrap(),
    );
    b.report_value("FOM_ratio_pipe (paper 6.3x)", fom_pacam / fom_p, "x");

    b.case("table6_assembly", || {
        std::hint::black_box(table6(&p));
    });
    b.finish();
}
