//! Hot-path microbenchmarks (§Perf of EXPERIMENTS.md).
//!
//! L3 request-path stages in isolation and end-to-end:
//! input encoding → full batch schedule (per registered backend, packed
//! `RowMask` spine vs the retired `Vec<bool>` baseline) → pipelined
//! stream. Baseline + after-optimization numbers are recorded in
//! EXPERIMENTS.md §Perf; the `packed_vs_boolmask_speedup` row is the
//! acceptance gate for the bit-packed selective-precharge refactor
//! (target: >= 2x on the multi-division scheduler path at batch 32).

use std::sync::Arc;

use dt2cam::api::{
    BackendOptions, Dt2Cam, MatchBackend, NativeBackend, PjrtBackend, ThreadedNativeBackend,
};
use dt2cam::config::EngineKind;
use dt2cam::coordinator::pipeline::run_pipeline;
use dt2cam::coordinator::{BatchScratch, InferenceRequest, Scheduler, ServingPlan};
use dt2cam::tcam::params::DeviceParams;
use dt2cam::util::benchkit::Bench;

/// The retired `Vec<Vec<bool>>` mask walk, kept verbatim as the bench
/// baseline: per-byte mask scans for energy/density, per-bool AND folds,
/// fresh allocations per batch/tile — exactly the pre-RowMask scheduler
/// + native kernel, so the speedup row measures the representation
/// change and nothing else.
mod boolmask_baseline {
    use super::ServingPlan;

    fn tile_match_bools(
        w_tile: &[f32],
        gthresh_tile: &[f32],
        s: usize,
        lane_bits: &[&[bool]],
        enabled: &[&[bool]],
        out: &mut [bool],
    ) {
        let lanes = lane_bits.len();
        let active: usize = enabled
            .iter()
            .map(|e| e.iter().filter(|&&x| x).count())
            .sum();
        let dense_cutoff = lanes * s / 8;
        if active >= dense_cutoff {
            let mut g = vec![0.0f32; s];
            for (lane, bits) in lane_bits.iter().enumerate() {
                g.iter_mut().for_each(|x| *x = 0.0);
                for (j, &b) in bits.iter().enumerate() {
                    let row_w =
                        &w_tile[(2 * j + usize::from(b)) * s..(2 * j + usize::from(b) + 1) * s];
                    for (acc, &wv) in g.iter_mut().zip(row_w) {
                        *acc += wv;
                    }
                }
                for r in 0..s {
                    out[lane * s + r] = g[r] < gthresh_tile[r];
                }
            }
        } else {
            for (lane, bits) in lane_bits.iter().enumerate() {
                for r in 0..s {
                    if !enabled[lane][r] {
                        continue;
                    }
                    let mut g = 0.0f32;
                    for (j, &b) in bits.iter().enumerate() {
                        g += w_tile[(2 * j + usize::from(b)) * s + r];
                    }
                    out[lane * s + r] = g < gthresh_tile[r];
                }
            }
        }
    }

    /// Sequential division walk over `Vec<bool>` masks (serial tiles —
    /// compare against the packed serial path via worker count 1).
    pub fn run_batch(
        plan: &ServingPlan,
        queries: &[Vec<bool>],
        real_lanes: usize,
    ) -> (Vec<Option<usize>>, u64) {
        let s = plan.s;
        let lanes = queries.len();
        let mut enabled: Vec<Vec<bool>> = (0..lanes)
            .map(|_| {
                let mut v = vec![false; plan.padded_rows];
                v[..plan.initially_active].fill(true);
                v
            })
            .collect();
        let mut energy_rows = 0u64;
        for (d, div) in plan.divisions.iter().enumerate() {
            for lane_enabled in enabled.iter().take(real_lanes) {
                energy_rows += lane_enabled.iter().filter(|&&e| e).count() as u64;
            }
            let col0 = d * s;
            let lane_bits: Vec<&[bool]> =
                queries.iter().map(|q| &q[col0..col0 + s]).collect();
            for rt in 0..plan.n_rwd {
                let w_tile = &div.w[rt * 2 * s * s..(rt + 1) * 2 * s * s];
                let gthresh_tile = &div.gthresh[rt * s..(rt + 1) * s];
                let en_refs: Vec<&[bool]> =
                    enabled.iter().map(|e| &e[rt * s..(rt + 1) * s]).collect();
                let mut out = vec![false; lanes * s];
                tile_match_bools(w_tile, gthresh_tile, s, &lane_bits, &en_refs, &mut out);
                for (lane, en) in enabled.iter_mut().enumerate() {
                    for r in 0..s {
                        let idx = rt * s + r;
                        en[idx] = en[idx] && out[lane * s + r];
                    }
                }
            }
        }
        let mut classes = Vec::with_capacity(lanes);
        for (lane, en) in enabled.iter().enumerate() {
            if lane >= real_lanes {
                classes.push(None);
                continue;
            }
            classes.push(
                en.iter()
                    .position(|&e| e)
                    .map(|first| plan.classes[first]),
            );
        }
        (classes, energy_rows)
    }
}

fn main() {
    let p = DeviceParams::default();
    let mut b = Bench::new("perf_hotpath");

    // Workload: covid is the paper's big *practical* dataset (Credit-scale
    // training is too slow for a microbench loop). Built once through the
    // typed facade; every stage below reuses the artifacts.
    let model = Dt2Cam::dataset("covid").unwrap();
    let program = model.compile();
    let s = 128;
    let mapped = program.map(s, &p);
    let m = mapped.primary();
    let plan = mapped.plan();
    b.report_line(&format!(
        "covid @S={s}: LUT {}x{}, grid {}x{}, plan W = {:.1} MiB",
        program.lut().n_rows(),
        program.lut().width(),
        m.n_rwd,
        m.n_cwd,
        plan.w_bytes() as f64 / (1 << 20) as f64
    ));

    // L3 stage 1: input encoding.
    let x = &model.test_x[0];
    b.case("encode_input (adaptive unary)", || {
        std::hint::black_box(program.lut().encode_input(x));
    });

    // L3 stage 2: one full batch through the sequential scheduler, per
    // backend (the pluggable seam's overhead must stay invisible here).
    let batch: Vec<Vec<bool>> = model.test_x[..32.min(model.test_x.len())]
        .iter()
        .map(|x| m.pad_query(&program.lut().encode_input(x)))
        .collect();
    let real = batch.len();
    let sched = Scheduler::new(&plan, &p);

    // The acceptance pair: Vec<bool> baseline vs the packed RowMask walk
    // (serial tiles on both sides — workers=1 disables fan-out — so the
    // row measures the mask representation, not threading). Sanity: both
    // must classify identically before being timed.
    let serial = ThreadedNativeBackend::new(1);
    let mut scratch = BatchScratch::default();
    {
        let (base_classes, base_energy) = boolmask_baseline::run_batch(&plan, &batch, real);
        let packed = sched
            .run_batch_with(&serial, &batch, real, &mut scratch)
            .unwrap();
        assert_eq!(packed.classes, base_classes, "baseline/packed divergence");
        assert_eq!(packed.active_row_evals, base_energy);
    }
    let base = b
        .case("scheduler_batch32_boolmask_baseline", || {
            std::hint::black_box(boolmask_baseline::run_batch(&plan, &batch, real));
        })
        .ns_per_iter
        .mean;
    let packed = b
        .case("scheduler_batch32_packed_serial", || {
            std::hint::black_box(
                sched
                    .run_batch_with(&serial, &batch, real, &mut scratch)
                    .unwrap(),
            );
        })
        .ns_per_iter
        .mean;
    b.report_value("packed_vs_boolmask_speedup", base / packed, "x (want >= 2)");

    let native = NativeBackend::new();
    b.case("scheduler_batch32_native", || {
        std::hint::black_box(sched.run_batch_with(&native, &batch, real, &mut scratch).unwrap());
    });
    let threaded = ThreadedNativeBackend::auto();
    b.case("scheduler_batch32_threaded_native", || {
        std::hint::black_box(
            sched
                .run_batch_with(&threaded, &batch, real, &mut scratch)
                .unwrap(),
        );
    });

    // PJRT path (if artifacts are present).
    let artifacts = std::path::Path::new("artifacts");
    if artifacts.join("manifest.json").exists() {
        let pjrt = PjrtBackend::from_dir(artifacts).unwrap();
        // warm
        let _ = sched.run_batch_with(&pjrt, &batch, real, &mut scratch).unwrap();
        b.case("scheduler_batch32_pjrt", || {
            std::hint::black_box(
                sched.run_batch_with(&pjrt, &batch, real, &mut scratch).unwrap(),
            );
        });
    } else {
        b.report_line("(skipping PJRT cases: run `make artifacts`)");
    }

    // Pipelined stream (8 batches in flight).
    let stream: Vec<(Vec<Vec<bool>>, usize)> = (0..8).map(|_| (batch.clone(), real)).collect();
    let plan_arc = Arc::new(plan.clone());
    let pipe_backend: Arc<dyn MatchBackend + Send + Sync> = Arc::new(NativeBackend::new());
    b.case("pipeline_8x32_native", || {
        std::hint::black_box(
            run_pipeline(
                Arc::clone(&plan_arc),
                Arc::clone(&pipe_backend),
                stream.clone(),
                2,
            )
            .unwrap(),
        );
    });

    // Forest vs single tree (ISSUE 3 acceptance row): a 9-bank forest
    // program served through bank-parallel dispatch, against (a) the
    // same program with banks walked sequentially, and (b) 9 separate
    // single-tree sessions run back to back. Haberman @S=16 keeps the
    // per-bank work small enough that bank fan-out — not tile fan-out —
    // dominates the parallel win.
    {
        use dt2cam::api::BankDispatch;
        use dt2cam::cart::ForestParams;
        use std::time::Instant;

        let fmodel = Dt2Cam::forest(
            "haberman",
            &ForestParams {
                n_trees: 9,
                sample_fraction: 0.8,
                max_features: 2,
                ..Default::default()
            },
        )
        .unwrap();
        let fmapped = fmodel.compile().map(16, &p);
        let fx: Vec<Vec<f64>> = fmodel.test_x.iter().take(32).cloned().collect();

        // Bank-parallel (registry dispatch for a Send + Sync backend).
        let mut par = fmapped.session(EngineKind::Native, 32).unwrap();
        // Sequential per-bank walk of the same program.
        let mut seq = fmapped
            .session_with_dispatch(
                BankDispatch::Sequential(Box::new(NativeBackend::new())),
                32,
            )
            .unwrap();
        // Sanity before timing: identical votes either way.
        assert_eq!(
            par.classify_all(&fx).unwrap(),
            seq.classify_all(&fx).unwrap(),
            "bank dispatch modes diverged"
        );

        let t_par = b
            .case("forest9_batch32_bank_parallel", || {
                std::hint::black_box(par.classify_all(&fx).unwrap());
            })
            .ns_per_iter
            .mean;
        let t_seq = b
            .case("forest9_batch32_bank_sequential", || {
                std::hint::black_box(seq.classify_all(&fx).unwrap());
            })
            .ns_per_iter
            .mean;
        b.report_value(
            "forest_bank_parallel_speedup",
            t_seq / t_par,
            "x (want > 1)",
        );

        // 9 sequential single-tree sessions over the same inputs (the
        // pre-bank workaround for ensembles): per-decision wall-clock.
        let smodel = Dt2Cam::dataset("haberman").unwrap();
        let smapped = smodel.compile().map(16, &p);
        let mut singles: Vec<_> = (0..9)
            .map(|_| smapped.session(EngineKind::Native, 32).unwrap())
            .collect();
        let t0 = Instant::now();
        let reps = 5;
        for _ in 0..reps {
            for sess in singles.iter_mut() {
                std::hint::black_box(sess.classify_all(&fx).unwrap());
            }
        }
        let single9_ns = t0.elapsed().as_nanos() as f64 / reps as f64;
        b.report_value(
            "forest_vs_single_tree",
            single9_ns / t_par.max(1.0),
            "x per-decision speedup of the 9-bank forest over 9 sequential single-tree sessions",
        );
    }

    // End-to-end serving throughput (native session), reported as dec/s.
    let mut session = mapped.session(EngineKind::Native, 32).unwrap();
    let n = model.test_x.len().min(512);
    let t0 = std::time::Instant::now();
    for (i, x) in model.test_x[..n].iter().enumerate() {
        session.submit(InferenceRequest::new(i as u64, x.clone()));
        let _ = session.poll(false).unwrap();
    }
    let _ = session.poll(true).unwrap();
    let wall = t0.elapsed().as_secs_f64();
    let inproc_tput = n as f64 / wall;
    b.report_value("serve_e2e_native_wall_throughput", inproc_tput, "dec/s");
    b.report_value(
        "modeled_seq_throughput",
        session.plan().timing.throughput_seq,
        "dec/s",
    );

    // ISSUE 5 acceptance rows: the streaming pipelined coordinator vs
    // the batch-sequential walk on the same covid @S=128 program at
    // batch 32 — in-process first, then behind the wire. Sanity before
    // timing: the two strategies must classify identically.
    let pipe_tput = {
        let inputs: Vec<Vec<f64>> = model.test_x[..n].to_vec();
        let mut seq_sess = mapped.session(EngineKind::Native, 32).unwrap();
        let mut pipe_sess = mapped
            .session_pipelined(EngineKind::Native, 32, &BackendOptions::default(), 4)
            .unwrap();
        assert_eq!(
            seq_sess.classify_all(&inputs).unwrap(),
            pipe_sess.classify_all(&inputs).unwrap(),
            "pipelined/sequential divergence"
        );
        let t_seq = b
            .case("serve_e2e_batch32_sequential", || {
                std::hint::black_box(seq_sess.classify_all(&inputs).unwrap());
            })
            .ns_per_iter
            .mean;
        let t_pipe = b
            .case("serve_e2e_batch32_pipelined", || {
                std::hint::black_box(pipe_sess.classify_all(&inputs).unwrap());
            })
            .ns_per_iter
            .mean;
        b.report_value(
            "pipelined_vs_sequential_speedup",
            t_seq / t_pipe,
            "x (streaming stage pipeline over batch-at-a-time walk)",
        );
        pipe_sess.metrics().modeled_pipe_throughput
    };
    // The paper's modeled pipelined figure (Table VI: f_max/3) next to
    // every wall number above, so the trajectory toward 333 M dec/s is
    // tracked in the same JSON artifact.
    b.report_value("modeled_pipe_throughput", pipe_tput, "dec/s");

    // ISSUE 4 acceptance row: the same covid program behind the wire —
    // in-process classify_all vs loopback socket throughput at batch 32
    // — so protocol + framing + routing overhead is tracked from day
    // one. 32 closed-loop clients keep ~a full batch of lanes in
    // flight, so the batcher coalesces across connections exactly like
    // the in-process path does within one stream.
    {
        use dt2cam::net::{self, Server, ServerConfig};
        let program_for_server = program.clone();
        let params = p.clone();
        let server = Server::spawn("127.0.0.1:0", ServerConfig::default(), move || {
            Ok(program_for_server
                .map(s, &params)
                .session(EngineKind::Native, 32)?
                .into_coordinator())
        })
        .unwrap();
        let addr = server.local_addr().to_string();
        let inputs: Vec<Vec<f64>> = model.test_x[..n].to_vec();
        // Warm the connection path once before timing.
        let _ = net::closed_loop(&addr, &inputs, 4, 32).unwrap();
        let report = net::closed_loop(&addr, &inputs, 32, n).unwrap();
        assert_eq!(report.completed, n as u64, "loopback run must answer everything");
        b.report_value("wire_loopback_wall_throughput", report.throughput(), "dec/s");
        b.report_value("wire_loopback_p99_latency_us", report.p99 * 1e6, "us");
        b.report_value(
            "inprocess_vs_wire_ratio",
            inproc_tput / report.throughput().max(1e-9),
            "x (in-process classify_all over loopback wire, batch 32)",
        );
        server.shutdown().unwrap();
    }

    // ISSUE 5 wire row: the same covid @S=128 program served
    // `--listen --pipelined` (streaming stage pipeline behind the
    // socket scheduler), 32 closed-loop clients at batch 32 — the wall
    // number CI tracks toward the paper's pipelined throughput.
    {
        use dt2cam::net::{self, Server, ServerConfig};
        let program_for_server = program.clone();
        let params = p.clone();
        let server = Server::spawn("127.0.0.1:0", ServerConfig::default(), move || {
            Ok(program_for_server
                .map(s, &params)
                .session_pipelined(EngineKind::Native, 32, &BackendOptions::default(), 4)?
                .into_coordinator())
        })
        .unwrap();
        let addr = server.local_addr().to_string();
        let inputs: Vec<Vec<f64>> = model.test_x[..n].to_vec();
        let _ = net::closed_loop(&addr, &inputs, 4, 32).unwrap(); // warm
        let report = net::closed_loop(&addr, &inputs, 32, n).unwrap();
        assert_eq!(report.completed, n as u64, "pipelined loopback must answer everything");
        b.report_value("wire_pipelined_wall_throughput", report.throughput(), "dec/s");
        b.report_value("wire_pipelined_p99_latency_us", report.p99 * 1e6, "us");
        server.shutdown().unwrap();
    }
    b.finish();

    // ISSUE 8 acceptance rows: the cross-bank row optimizer on the two
    // 9-bank reference forests. A separate Bench title so CI archives
    // BENCH_opt_rows.json alongside the hot-path trajectory. Sanity
    // before reporting: the L2-optimized program must classify a batch
    // bit-identically to the unoptimized one.
    {
        use dt2cam::cart::ForestParams;
        use dt2cam::opt::OptLevel;

        let mut ob = Bench::new("opt_rows");
        for ds in ["covid", "haberman"] {
            let fmodel = Dt2Cam::forest(
                ds,
                &ForestParams {
                    n_trees: 9,
                    sample_fraction: 0.8,
                    max_features: 2,
                    ..Default::default()
                },
            )
            .unwrap();
            let program = fmodel.compile();
            let (optimized, report) = program.optimize(OptLevel::L2).unwrap();

            let fx: Vec<Vec<f64>> = fmodel.test_x.iter().take(32).cloned().collect();
            let mut base = program.map(16, &p).session(EngineKind::Native, 32).unwrap();
            let mut opt = optimized.map(16, &p).session(EngineKind::Native, 32).unwrap();
            assert_eq!(
                base.classify_all(&fx).unwrap(),
                opt.classify_all(&fx).unwrap(),
                "optimizer changed classification on {ds}"
            );

            ob.report_line(&report.summary_line());
            ob.report_value(
                &format!("rows_after_dedup_ratio_{ds}"),
                report.rows_after_dedup_ratio(),
                "physical/baseline rows (want < 1)",
            );
            ob.report_value(
                &format!("forest_energy_saving_{ds}"),
                report.forest_energy_saving(),
                "fraction of modeled search energy removed (want > 0)",
            );
        }
        ob.finish();
    }

    // ISSUE 9 acceptance rows: the observability plane's overhead
    // contract, on the same covid program behind the loopback wire.
    // The trace plane is compiled in unconditionally, so "baseline"
    // and "trace off" are two runs of the identical default config:
    // their ratio bounds run-to-run noise plus the dormant plane's
    // cost (one sampling branch per admitted request). The third run
    // samples every request (`--trace-sample 1`), the worst case.
    {
        use dt2cam::net::{self, Server, ServerConfig};

        let mut obb = Bench::new("obs_overhead");
        let inputs: Vec<Vec<f64>> = model.test_x[..n].to_vec();
        let run = |trace_sample: u64| -> f64 {
            let program_for_server = program.clone();
            let params = p.clone();
            let server = Server::spawn(
                "127.0.0.1:0",
                ServerConfig {
                    trace_sample,
                    ..Default::default()
                },
                move || {
                    Ok(program_for_server
                        .map(s, &params)
                        .session(EngineKind::Native, 32)?
                        .into_coordinator())
                },
            )
            .unwrap();
            let addr = server.local_addr().to_string();
            let _ = net::closed_loop(&addr, &inputs, 4, 32).unwrap(); // warm
            let report = net::closed_loop(&addr, &inputs, 32, inputs.len()).unwrap();
            assert_eq!(
                report.completed,
                inputs.len() as u64,
                "obs overhead run must answer everything"
            );
            server.shutdown().unwrap();
            report.throughput()
        };
        let t_baseline = run(0);
        let t_off = run(0);
        let t_on = run(1);
        obb.report_value("wall_throughput_baseline", t_baseline, "dec/s");
        obb.report_value("wall_throughput_trace_off", t_off, "dec/s");
        obb.report_value("wall_throughput_trace_on", t_on, "dec/s");
        obb.report_value(
            "trace_off_vs_baseline_ratio",
            t_off / t_baseline.max(1e-9),
            "x (want >= 0.97: a dormant tracer is one branch per request)",
        );
        obb.report_value(
            "trace_on_overhead_pct",
            (1.0 - t_on / t_baseline.max(1e-9)) * 100.0,
            "% (want <= 10: 1-in-1 sampling vs the untraced baseline)",
        );
        obb.finish();
    }
}
