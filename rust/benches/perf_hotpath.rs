//! Hot-path microbenchmarks (§Perf of EXPERIMENTS.md).
//!
//! L3 request-path stages in isolation and end-to-end:
//! input encoding → tile match (native f32) → full batch schedule
//! (native vs PJRT) → pipelined stream. Baseline + after-optimization
//! numbers are recorded in EXPERIMENTS.md §Perf.

use std::sync::Arc;

use dt2cam::config::{EngineKind, RunConfig};
use dt2cam::coordinator::pipeline::run_pipeline;
use dt2cam::coordinator::scheduler::{EngineRef, Scheduler};
use dt2cam::coordinator::{Coordinator, InferenceRequest, ServingPlan};
use dt2cam::report::workload::Workload;
use dt2cam::runtime::MatchEngine;
use dt2cam::tcam::params::DeviceParams;
use dt2cam::util::benchkit::Bench;

fn main() {
    let p = DeviceParams::default();
    let mut b = Bench::new("perf_hotpath");

    // Workload: covid is the paper's big *practical* dataset (Credit-scale
    // training is too slow for a microbench loop).
    let w = Workload::prepare("covid").unwrap();
    let s = 128;
    let m = w.map(s, &p);
    let plan = ServingPlan::build(&m, &m.vref, &p);
    b.report_line(&format!(
        "covid @S={s}: LUT {}x{}, grid {}x{}, plan W = {:.1} MiB",
        w.lut.n_rows(),
        w.lut.width(),
        m.n_rwd,
        m.n_cwd,
        plan.w_bytes() as f64 / (1 << 20) as f64
    ));

    // L3 stage 1: input encoding.
    let x = &w.test_x[0];
    b.case("encode_input (adaptive unary)", || {
        std::hint::black_box(w.lut.encode_input(x));
    });

    // L3 stage 2: one full batch through the sequential scheduler.
    let batch: Vec<Vec<bool>> = w.test_x[..32.min(w.test_x.len())]
        .iter()
        .map(|x| m.pad_query(&w.lut.encode_input(x)))
        .collect();
    let real = batch.len();
    let sched = Scheduler::new(&plan, &p);
    b.case("scheduler_batch32_native", || {
        std::hint::black_box(sched.run_batch(&EngineRef::Native, &batch, real).unwrap());
    });

    // PJRT path (if artifacts are present).
    let artifacts = std::path::Path::new("artifacts");
    if artifacts.join("manifest.json").exists() {
        let eng = MatchEngine::new(artifacts).unwrap();
        // warm
        let _ = sched.run_batch(&EngineRef::Pjrt(&eng), &batch, real).unwrap();
        b.case("scheduler_batch32_pjrt", || {
            std::hint::black_box(
                sched.run_batch(&EngineRef::Pjrt(&eng), &batch, real).unwrap(),
            );
        });
    } else {
        b.report_line("(skipping PJRT cases: run `make artifacts`)");
    }

    // Pipelined stream (8 batches in flight).
    let stream: Vec<(Vec<Vec<bool>>, usize)> = (0..8).map(|_| (batch.clone(), real)).collect();
    let plan_arc = Arc::new(plan.clone());
    b.case("pipeline_8x32_native", || {
        std::hint::black_box(
            run_pipeline(Arc::clone(&plan_arc), stream.clone(), 2).unwrap(),
        );
    });

    // End-to-end serving throughput (native), reported as dec/s.
    let cfg = RunConfig {
        dataset: "covid".into(),
        tile_size: s,
        batch: 32,
        engine: EngineKind::Native,
        ..RunConfig::default()
    };
    let mut coord = Coordinator::new(&cfg, w.lut.clone(), &m, &m.vref.clone(), p.clone()).unwrap();
    let n = w.test_x.len().min(512);
    let t0 = std::time::Instant::now();
    for (i, x) in w.test_x[..n].iter().enumerate() {
        coord.submit(InferenceRequest::new(i as u64, x.clone()));
        let _ = coord.poll(false).unwrap();
    }
    let _ = coord.poll(true).unwrap();
    let wall = t0.elapsed().as_secs_f64();
    b.report_value("serve_e2e_native_wall_throughput", n as f64 / wall, "dec/s");
    b.report_value(
        "modeled_seq_throughput",
        coord.plan().timing.throughput_seq,
        "dec/s",
    );
    b.finish();
}
