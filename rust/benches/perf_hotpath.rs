//! Hot-path microbenchmarks (§Perf of EXPERIMENTS.md).
//!
//! L3 request-path stages in isolation and end-to-end:
//! input encoding → tile match (native f32) → full batch schedule
//! (per registered backend) → pipelined stream. Baseline +
//! after-optimization numbers are recorded in EXPERIMENTS.md §Perf.

use std::sync::Arc;

use dt2cam::api::{Dt2Cam, MatchBackend, NativeBackend, PjrtBackend, ThreadedNativeBackend};
use dt2cam::config::EngineKind;
use dt2cam::coordinator::pipeline::run_pipeline;
use dt2cam::coordinator::{InferenceRequest, Scheduler};
use dt2cam::tcam::params::DeviceParams;
use dt2cam::util::benchkit::Bench;

fn main() {
    let p = DeviceParams::default();
    let mut b = Bench::new("perf_hotpath");

    // Workload: covid is the paper's big *practical* dataset (Credit-scale
    // training is too slow for a microbench loop). Built once through the
    // typed facade; every stage below reuses the artifacts.
    let model = Dt2Cam::dataset("covid").unwrap();
    let program = model.compile();
    let s = 128;
    let mapped = program.map(s, &p);
    let m = &mapped.mapped;
    let plan = mapped.plan();
    b.report_line(&format!(
        "covid @S={s}: LUT {}x{}, grid {}x{}, plan W = {:.1} MiB",
        program.lut.n_rows(),
        program.lut.width(),
        m.n_rwd,
        m.n_cwd,
        plan.w_bytes() as f64 / (1 << 20) as f64
    ));

    // L3 stage 1: input encoding.
    let x = &model.test_x[0];
    b.case("encode_input (adaptive unary)", || {
        std::hint::black_box(program.lut.encode_input(x));
    });

    // L3 stage 2: one full batch through the sequential scheduler, per
    // backend (the pluggable seam's overhead must stay invisible here).
    let batch: Vec<Vec<bool>> = model.test_x[..32.min(model.test_x.len())]
        .iter()
        .map(|x| m.pad_query(&program.lut.encode_input(x)))
        .collect();
    let real = batch.len();
    let sched = Scheduler::new(&plan, &p);
    let native = NativeBackend::new();
    b.case("scheduler_batch32_native", || {
        std::hint::black_box(sched.run_batch(&native, &batch, real).unwrap());
    });
    let threaded = ThreadedNativeBackend::auto();
    b.case("scheduler_batch32_threaded_native", || {
        std::hint::black_box(sched.run_batch(&threaded, &batch, real).unwrap());
    });

    // PJRT path (if artifacts are present).
    let artifacts = std::path::Path::new("artifacts");
    if artifacts.join("manifest.json").exists() {
        let pjrt = PjrtBackend::from_dir(artifacts).unwrap();
        // warm
        let _ = sched.run_batch(&pjrt, &batch, real).unwrap();
        b.case("scheduler_batch32_pjrt", || {
            std::hint::black_box(sched.run_batch(&pjrt, &batch, real).unwrap());
        });
    } else {
        b.report_line("(skipping PJRT cases: run `make artifacts`)");
    }

    // Pipelined stream (8 batches in flight).
    let stream: Vec<(Vec<Vec<bool>>, usize)> = (0..8).map(|_| (batch.clone(), real)).collect();
    let plan_arc = Arc::new(plan.clone());
    let pipe_backend: Arc<dyn MatchBackend + Send + Sync> = Arc::new(NativeBackend::new());
    b.case("pipeline_8x32_native", || {
        std::hint::black_box(
            run_pipeline(
                Arc::clone(&plan_arc),
                Arc::clone(&pipe_backend),
                stream.clone(),
                2,
            )
            .unwrap(),
        );
    });

    // End-to-end serving throughput (native session), reported as dec/s.
    let mut session = mapped.session(EngineKind::Native, 32).unwrap();
    let n = model.test_x.len().min(512);
    let t0 = std::time::Instant::now();
    for (i, x) in model.test_x[..n].iter().enumerate() {
        session.submit(InferenceRequest::new(i as u64, x.clone()));
        let _ = session.poll(false).unwrap();
    }
    let _ = session.poll(true).unwrap();
    let wall = t0.elapsed().as_secs_f64();
    b.report_value("serve_e2e_native_wall_throughput", n as f64 / wall, "dec/s");
    b.report_value(
        "modeled_seq_throughput",
        session.plan().timing.throughput_seq,
        "dec/s",
    );
    b.finish();
}
