//! Bench/regeneration target for paper Fig 7: % accuracy loss under input
//! noise, SA variability, and stuck-at faults, for Diabetes / Covid /
//! Cancer across tile sizes.
//!
//! Default uses a reduced grid (same axes, fewer points/trials) so
//! `cargo bench` stays minutes-scale; DT2CAM_BENCH_FULL=1 runs the paper's
//! full grid.

use dt2cam::report::figures::{fig7, render_fig7, NonidealGrid};
use dt2cam::report::workload::Workload;
use dt2cam::tcam::params::DeviceParams;
use dt2cam::util::benchkit::Bench;

fn main() {
    let full = std::env::var("DT2CAM_BENCH_FULL").is_ok();
    let p = DeviceParams::default();
    let grid = if full {
        NonidealGrid::default()
    } else {
        NonidealGrid {
            sigma_in: vec![0.0, 0.01, 0.1],
            sigma_sa: vec![0.0, 0.05, 0.1],
            saf_pct: vec![0.0, 0.1, 0.5],
            tile_sizes: vec![16, 64, 128],
            trials: 2,
            max_inputs: 256,
        }
    };

    let mut b = Bench::new("fig7_nonideal");
    for name in ["diabetes", "covid", "cancer"] {
        let w = Workload::prepare(name).unwrap();
        let pts = fig7(&w, &p, &grid);
        for line in render_fig7(&pts).lines() {
            b.report_line(line);
        }

        // Shape checks (paper §IV.B): clean point == golden; SAF is the
        // worst offender.
        let clean = pts
            .iter()
            .find(|q| q.sigma_in == 0.0 && q.sigma_sa == 0.0 && q.saf_pct == 0.0)
            .unwrap();
        assert!(
            clean.acc_loss_pp.abs() < 1e-9,
            "{name}: ideal hardware must match golden accuracy"
        );
        let worst_saf = pts
            .iter()
            .filter(|q| q.saf_pct >= 0.5 && q.sigma_in == 0.0 && q.sigma_sa == 0.0)
            .map(|q| q.acc_loss_pp)
            .fold(f64::NEG_INFINITY, f64::max);
        let worst_noise = pts
            .iter()
            .filter(|q| q.saf_pct == 0.0 && q.sigma_sa == 0.0)
            .map(|q| q.acc_loss_pp)
            .fold(f64::NEG_INFINITY, f64::max);
        b.report_value(&format!("{name}: worst SAF loss"), worst_saf, "pp");
        b.report_value(&format!("{name}: worst input-noise loss"), worst_noise, "pp");
    }

    let w = Workload::prepare("cancer").unwrap();
    b.case("fig7_cancer_quick_grid", || {
        std::hint::black_box(fig7(&w, &p, &NonidealGrid::quick()));
    });
    b.finish();
}
