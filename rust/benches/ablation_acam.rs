//! Ablation: DT2CAM's ternary-TCAM realization vs the ACAM realization
//! (paper §V future work / the §IV.C comparator), computed per dataset
//! from the *same trees* — cells, area, energy, and where each wins.
//!
//! Expected shape (paper §IV.C): ACAM rows are much narrower (one cell
//! per feature) but each analog cell is ~18x larger than a 2T2R bit
//! (0.299 vs 0.017 µm²/bit), and ACAM has no selective precharge, so
//! DT2CAM wins area and energy while ACAM wins raw row count.

use dt2cam::acam::{acam_report, AcamArray, AcamParams};
use dt2cam::report::workload::Workload;
use dt2cam::synth::area::area;
use dt2cam::tcam::params::DeviceParams;
use dt2cam::util::benchkit::Bench;
use dt2cam::util::prng::Prng;

fn main() {
    let p = DeviceParams::default();
    let ap = AcamParams::default();
    let mut b = Bench::new("ablation_acam");
    b.report_line(
        "dataset     TCAM cells  ACAM cells  TCAM mm2   ACAM mm2   TCAM nJ    ACAM nJ",
    );
    for name in ["iris", "diabetes", "haberman", "car", "cancer", "titanic", "covid"] {
        let w = Workload::prepare(name).unwrap();
        // TCAM realization @ S chosen by Table IV for D=0.2.
        let s = 128;
        let mut rng = Prng::new(1);
        let m = dt2cam::synth::mapping::MappedArray::from_lut(&w.lut, s, &p, &mut rng);
        let tcam_area = area(m.n_tiles(), s, m.n_classes, &p);
        let r = dt2cam::synth::simulate::simulate(
            &m,
            &w.lut,
            &w.test_x,
            &w.test_y,
            &w.golden,
            &m.vref,
            &p,
            &dt2cam::synth::simulate::SimOptions {
                max_inputs: 256,
                ..Default::default()
            },
        );

        // ACAM realization of the same tree.
        let acam = AcamArray::from_lut(&w.lut);
        let ar = acam_report(&acam, &ap);

        // Functional equivalence of the two realizations.
        for x in w.test_x.iter().take(64) {
            assert_eq!(
                acam.classify(x),
                w.lut.classify(x),
                "{name}: ACAM and TCAM must classify identically"
            );
        }

        b.report_line(&format!(
            "{name:<11} {:>10} {:>11} {:>9.4} {:>10.4} {:>9.4} {:>9.4}",
            tcam_area.n_cells,
            ar.n_cells,
            tcam_area.total_mm2,
            ar.area_mm2,
            r.energy_per_dec * 1e9,
            ar.energy_per_dec * 1e9,
        ));
    }
    b.report_line("[small datasets: ACAM wins — SxS padding dominates the TCAM at S=128;");
    b.report_line(" pick S from Table IV per deployment. At the paper's traffic scale the");
    b.report_line(" trade flips (below): 2T2R cells are ~18x smaller and SP + rogue-row");
    b.report_line(" gating cut energy — the paper's §IV.C headline.]");

    // Traffic-scale comparison from both of our models (Table VI check).
    let ours = dt2cam::report::sota::dt2cam_traffic_rows(&p);
    let acam_traffic = dt2cam::acam::AcamArray {
        cells: vec![dt2cam::acam::AcamCell::always_match(); 2000 * 256],
        n_rows: 2000,
        n_features: 256,
        classes: vec![0; 2000],
        n_classes: 2,
    };
    let ar = acam_report(&acam_traffic, &ap);
    b.report_value(
        "traffic energy ratio ACAM/DT2CAM (paper 1.73x)",
        ar.energy_per_dec / ours[0].energy_per_dec,
        "x",
    );
    b.report_value(
        "traffic area ratio ACAM-core/DT2CAM",
        ar.area_mm2 / ours[0].area_mm2.unwrap(),
        "x",
    );
    assert!(
        ours[0].energy_per_dec < ar.energy_per_dec,
        "DT2CAM must win energy at traffic scale"
    );

    let w = Workload::prepare("iris").unwrap();
    b.case("acam_build_from_lut", || {
        std::hint::black_box(AcamArray::from_lut(&w.lut));
    });
    let acam = AcamArray::from_lut(&w.lut);
    let x = w.test_x[0].clone();
    b.case("acam_classify", || {
        std::hint::black_box(acam.classify(&x));
    });
    b.finish();
}
