//! Non-ideality robustness sweep (paper Figs 7 and 8).
//!
//! Sweeps stuck-at-fault rates, sense-amp Vref variability and input
//! encoding noise over the paper's three study datasets and prints the
//! accuracy-loss surfaces. Use `--full` for the paper's complete grid.
//!
//! ```sh
//! cargo run --release --example nonidealities [-- --full]
//! ```

use dt2cam::report::figures::{fig7, fig8, render_fig7, render_fig8, NonidealGrid};
use dt2cam::report::workload::Workload;
use dt2cam::tcam::params::DeviceParams;

fn main() -> anyhow::Result<()> {
    let full = std::env::args().any(|a| a == "--full");
    let p = DeviceParams::default();
    let grid = if full {
        NonidealGrid::default()
    } else {
        NonidealGrid {
            sigma_in: vec![0.0, 0.005, 0.02, 0.1],
            sigma_sa: vec![0.0, 0.03, 0.05, 0.1],
            saf_pct: vec![0.0, 0.1, 0.5],
            tile_sizes: vec![16, 64, 128],
            trials: 2,
            max_inputs: 256,
        }
    };

    let mut workloads = Vec::new();
    for name in ["diabetes", "covid", "cancer"] {
        eprintln!("preparing {name}...");
        workloads.push(Workload::prepare(name)?);
    }

    println!("== Fig 7: accuracy loss under non-idealities ==");
    for w in &workloads {
        let pts = fig7(w, &p, &grid);
        print!("{}", render_fig7(&pts));

        // Paper's qualitative findings, verified per dataset:
        let clean_ok = pts
            .iter()
            .filter(|q| q.saf_pct == 0.0 && q.sigma_sa == 0.0 && q.sigma_in == 0.0)
            .all(|q| q.acc_loss_pp.abs() < 1e-9);
        println!(
            "  {}: ideal==golden {} | SAF dominates {}",
            w.dataset.name,
            if clean_ok { "yes" } else { "NO" },
            {
                let worst_saf = pts
                    .iter()
                    .filter(|q| q.saf_pct > 0.0)
                    .map(|q| q.acc_loss_pp)
                    .fold(f64::NEG_INFINITY, f64::max);
                let worst_rest = pts
                    .iter()
                    .filter(|q| q.saf_pct == 0.0)
                    .map(|q| q.acc_loss_pp)
                    .fold(f64::NEG_INFINITY, f64::max);
                if worst_saf >= worst_rest { "yes" } else { "no (this seed)" }
            }
        );
    }

    println!("\n== Fig 8: accuracy loss vs #tiles ==");
    let wrefs: Vec<&Workload> = workloads.iter().collect();
    let pts = fig8(&wrefs, &p, &[0.0, 0.1, 0.5], grid.trials);
    print!("{}", render_fig8(&pts));
    Ok(())
}
