//! Quickstart: the full DT2CAM flow on Iris in ~40 lines.
//!
//! Train a CART tree → DT-HW-compile it to a ternary LUT → map onto S×S
//! ReCAM tiles → run the functional simulation on the held-out split →
//! print accuracy / energy / latency. (The paper's Fig 2 walks exactly
//! this dataset through the same stages.)
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use dt2cam::report::workload::Workload;
use dt2cam::synth::simulate::{simulate, SimOptions};
use dt2cam::tcam::params::DeviceParams;
use dt2cam::util::stats::eng;

fn main() -> anyhow::Result<()> {
    // 1. Dataset → CART tree → ternary LUT (the DT-HW compiler).
    let w = Workload::prepare("iris")?;
    println!(
        "tree: {} leaves (= LUT rows), depth {}",
        w.tree.n_leaves(),
        w.tree.depth()
    );
    println!("LUT : {} x {} trits", w.lut.n_rows(), w.lut.width());
    for r in 0..w.lut.n_rows().min(3) {
        println!("  row {r}: {}  -> class {}", w.lut.row_to_string(r), w.lut.classes[r]);
    }

    // 2. Map onto 16x16 resistive TCAM tiles (ReCAM synthesizer).
    let p = DeviceParams::default();
    let m = w.map(16, &p);
    println!(
        "tiles: {} x {} of {}x{} (decoder column + {} rogue rows)",
        m.n_rwd,
        m.n_cwd,
        m.s,
        m.s,
        m.padded_rows - m.real_rows
    );

    // 3. Functional simulation on the 10% test split.
    let r = simulate(
        &m, &w.lut, &w.test_x, &w.test_y, &w.golden, &m.vref, &p,
        &SimOptions::default(),
    );
    println!("accuracy : {:.4} (golden {:.4})", r.accuracy, w.golden_accuracy());
    println!("energy   : {}", eng(r.energy_per_dec, "J/dec"));
    println!("latency  : {}", eng(r.timing.latency, "s"));
    println!("throughput (seq) : {}", eng(r.timing.throughput_seq, "dec/s"));
    println!("throughput (pipe): {}", eng(r.timing.throughput_pipe, "dec/s"));
    assert_eq!(r.golden_agreement, 1.0, "ideal hardware must match golden");
    println!("ok: ReCAM classification matches the software tree exactly");
    Ok(())
}
