//! Quickstart: the full DT2CAM flow on Iris through the typed pipeline
//! facade in ~40 lines.
//!
//! `Dt2Cam::dataset` (CART training) → `TrainedModel::compile` (ternary
//! LUT) → `CompiledProgram::map` (S×S ReCAM tiles) → `Session` (serving
//! coordinator over a pluggable match backend). The paper's Fig 2 walks
//! exactly this dataset through the same stages.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use dt2cam::api::Dt2Cam;
use dt2cam::config::EngineKind;
use dt2cam::synth::simulate::{simulate, SimOptions};
use dt2cam::tcam::params::DeviceParams;
use dt2cam::util::stats::eng;

fn main() -> anyhow::Result<()> {
    // 1. Dataset → CART tree (the expensive, once-per-program stage).
    let model = Dt2Cam::dataset("iris")?;
    println!(
        "tree: {} leaves (= LUT rows), depth {}",
        model.tree().n_leaves(),
        model.tree().depth()
    );

    // 2. DT-HW compile: tree → ternary LUT + input encoders (a 1-bank
    //    program; `Dt2Cam::forest` yields the N-bank generalization).
    let program = model.compile();
    println!("LUT : {} x {} trits", program.lut().n_rows(), program.lut().width());
    for r in 0..program.lut().n_rows().min(3) {
        println!(
            "  row {r}: {}  -> class {}",
            program.lut().row_to_string(r),
            program.lut().classes[r]
        );
    }

    // 3. Map onto 16x16 resistive TCAM tiles (ReCAM synthesizer).
    let p = DeviceParams::default();
    let mapped = program.map(16, &p);
    let m = mapped.primary();
    println!(
        "tiles: {} x {} of {}x{} (decoder column + {} rogue rows)",
        m.n_rwd,
        m.n_cwd,
        m.s,
        m.s,
        m.padded_rows - m.real_rows
    );

    // 4. Functional simulation on the 10% test split.
    let r = simulate(
        m, program.lut(), &model.test_x, &model.test_y, &model.golden, &m.vref, &p,
        &SimOptions::default(),
    );
    println!("accuracy : {:.4} (golden {:.4})", r.accuracy, model.golden_accuracy());
    println!("energy   : {}", eng(r.energy_per_dec, "J/dec"));
    println!("latency  : {}", eng(r.timing.latency, "s"));
    println!("throughput (seq) : {}", eng(r.timing.throughput_seq, "dec/s"));
    println!("throughput (pipe): {}", eng(r.timing.throughput_pipe, "dec/s"));
    assert_eq!(r.golden_agreement, 1.0, "ideal hardware must match golden");

    // 5. Serve the same split through a live session (native backend).
    let mut session = mapped.session(EngineKind::Native, 8)?;
    let classes = session.classify_all(&model.test_x)?;
    let agree = classes
        .iter()
        .zip(&model.golden)
        .filter(|(c, g)| **c == Some(**g))
        .count();
    println!(
        "session ({}): {}/{} classifications match the software tree",
        session.backend_name(),
        agree,
        classes.len()
    );
    assert_eq!(agree, classes.len());
    println!("ok: ReCAM classification matches the software tree exactly");
    Ok(())
}
