//! One-off PJRT dispatch-cost probe used during the §Perf pass
//! (EXPERIMENTS.md) — kept for re-profiling artifact variants. Opens the
//! artifacts through the `pjrt` match backend and probes its raw engine.

use dt2cam::api::PjrtBackend;
use std::time::Instant;

fn main() {
    let backend = PjrtBackend::from_dir(std::path::Path::new("artifacts")).unwrap();
    let eng = backend.engine();
    let (s, b) = (128usize, 32usize);
    println!("selected tile artifact: {}", eng.manifest().tile(s, b).unwrap().name);
    println!("selected div t=4 artifact: {}", eng.manifest().division(s, b, 4).unwrap().name);
    let q = vec![0.5f32; b * 2 * s];
    let w = vec![1e-5f32; 2 * s * s];
    let vref = vec![0.4f32; s];
    for _ in 0..3 {
        let _ = eng.match_tile(s, b, &q, &w, &vref, 1.4e4).unwrap();
    }
    let t0 = Instant::now();
    let n = 100;
    for _ in 0..n {
        let _ = eng.match_tile(s, b, &q, &w, &vref, 1.4e4).unwrap();
    }
    println!(
        "match_tile s128 b32: {:.1} us/call",
        t0.elapsed().as_secs_f64() * 1e6 / n as f64
    );
    let wd = vec![1e-5f32; 4 * 2 * s * s];
    let vrd = vec![0.4f32; 4 * s];
    for _ in 0..3 {
        let _ = eng.match_division(s, b, 4, &q, &wd, &vrd, 1.4e4).unwrap();
    }
    let t0 = Instant::now();
    for _ in 0..n {
        let _ = eng.match_division(s, b, 4, &q, &wd, &vrd, 1.4e4).unwrap();
    }
    println!(
        "match_division s128 b32 t4: {:.1} us/call",
        t0.elapsed().as_secs_f64() * 1e6 / n as f64
    );
}
