//! End-to-end serving driver (the repository's E2E validation run —
//! recorded in EXPERIMENTS.md §E2E).
//!
//! Proves all layers compose on a real workload:
//!  * L1/L2 — the Pallas/JAX match graph, AOT-lowered to HLO text by
//!    `make artifacts`, executed through PJRT from Rust;
//!  * L3 — the coordinator: request stream → dynamic batcher → per-
//!    division stage scheduling with selective precharge → class readout;
//!  * plus the native engine as a differential oracle: both engines must
//!    produce identical classifications.
//!
//! Workload: the Covid dataset (33.6k instances, Table II) — train CART
//! on 90%, serve the 10% split (3.36k requests) through the mapped ReCAM.
//!
//! ```sh
//! make artifacts && cargo run --release --example serve_e2e
//! ```

use std::time::Instant;

use dt2cam::config::{EngineKind, RunConfig};
use dt2cam::coordinator::{Coordinator, InferenceRequest};
use dt2cam::report::workload::Workload;
use dt2cam::tcam::params::DeviceParams;
use dt2cam::util::stats::eng;

fn serve(
    engine: EngineKind,
    w: &Workload,
    s: usize,
    batch: usize,
    n: usize,
) -> anyhow::Result<(Vec<Option<usize>>, f64, f64)> {
    let p = DeviceParams::default();
    let m = w.map(s, &p);
    let cfg = RunConfig {
        dataset: w.dataset.name.clone(),
        tile_size: s,
        batch,
        engine,
        ..RunConfig::default()
    };
    let vref = m.vref.clone();
    let mut coord = Coordinator::new(&cfg, w.lut.clone(), &m, &vref, p)?;

    let t0 = Instant::now();
    let mut responses = Vec::with_capacity(n);
    for (i, x) in w.test_x[..n].iter().enumerate() {
        coord.submit(InferenceRequest::new(i as u64, x.clone()));
        responses.extend(coord.poll(false)?);
    }
    responses.extend(coord.poll(true)?);
    let wall = t0.elapsed().as_secs_f64();

    responses.sort_by_key(|r| r.id);
    let classes: Vec<Option<usize>> = responses.iter().map(|r| r.class).collect();
    Ok((classes, wall, coord.metrics.energy_per_dec()))
}

fn main() -> anyhow::Result<()> {
    let has_artifacts = std::path::Path::new("artifacts/manifest.json").exists();
    println!("== DT2CAM end-to-end serving (covid @ S=128, batch 32) ==");
    let w = Workload::prepare("covid")?;
    let n = w.test_x.len();
    println!(
        "workload: {} train / {} serve requests, LUT {}x{}",
        w.split.train.len(),
        n,
        w.lut.n_rows(),
        w.lut.width()
    );

    // Native engine first (always available).
    let (native, wall_native, e_native) = serve(EngineKind::Native, &w, 128, 32, n)?;
    let acc = |cls: &[Option<usize>]| {
        cls.iter()
            .zip(&w.test_y[..n])
            .filter(|(c, y)| **c == Some(**y))
            .count() as f64
            / n as f64
    };
    println!(
        "native: {n} decisions in {wall_native:.3}s -> {:.0} dec/s wall, accuracy {:.4}, modeled {}",
        n as f64 / wall_native,
        acc(&native),
        eng(e_native, "J/dec"),
    );

    if has_artifacts {
        let (pjrt, wall_pjrt, e_pjrt) = serve(EngineKind::Pjrt, &w, 128, 32, n)?;
        println!(
            "pjrt  : {n} decisions in {wall_pjrt:.3}s -> {:.0} dec/s wall, accuracy {:.4}, modeled {}",
            n as f64 / wall_pjrt,
            acc(&pjrt),
            eng(e_pjrt, "J/dec"),
        );
        assert_eq!(native, pjrt, "engines must agree on every classification");
        println!("ok: PJRT artifacts and native simulator agree on all {n} decisions");
    } else {
        println!("(PJRT pass skipped: run `make artifacts` first)");
    }

    // Golden check: ideal hardware == software tree.
    let golden_agree = native
        .iter()
        .zip(&w.golden[..n])
        .filter(|(c, g)| **c == Some(**g))
        .count();
    assert_eq!(golden_agree, n, "ideal hardware must match golden predictions");
    println!(
        "golden agreement {}/{} | golden accuracy {:.4}",
        golden_agree,
        n,
        w.golden_accuracy()
    );
    Ok(())
}
