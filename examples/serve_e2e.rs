//! End-to-end serving driver (the repository's E2E validation run —
//! recorded in EXPERIMENTS.md §E2E).
//!
//! Proves all layers compose on a real workload through the typed
//! pipeline facade:
//!  * L1/L2 — the Pallas/JAX match graph, AOT-lowered to HLO text by
//!    `make artifacts`, executed through the `pjrt` match backend;
//!  * L3 — the coordinator session: request stream → dynamic batcher →
//!    per-division stage scheduling with selective precharge → class
//!    readout;
//!  * plus the `native` and `threaded-native` backends as differential
//!    oracles: every registered backend must produce identical
//!    classifications.
//!
//! Workload: the Covid dataset (33.6k instances, Table II) — train CART
//! on 90%, serve the 10% split (3.36k requests) through the mapped ReCAM.
//!
//! ```sh
//! make artifacts && cargo run --release --example serve_e2e
//! ```
//!
//! This example drives the session *in-process*; the same coordinator
//! also serves over a real network boundary — the `net` module's framed
//! TCP socket path (`dt2cam serve --listen ADDR` on one terminal,
//! `dt2cam loadgen --connect ADDR` on another). See
//! `examples/net_serve.rs` for that flow end to end.

use std::time::Instant;

use dt2cam::api::{Dt2Cam, MappedProgram};
use dt2cam::config::EngineKind;
use dt2cam::coordinator::InferenceRequest;
use dt2cam::tcam::params::DeviceParams;
use dt2cam::util::stats::eng;

fn serve(
    engine: EngineKind,
    mapped: &MappedProgram,
    test_x: &[Vec<f64>],
    batch: usize,
) -> anyhow::Result<(Vec<Option<usize>>, f64, f64)> {
    let mut session = mapped.session(engine, batch)?;

    let t0 = Instant::now();
    let n = test_x.len();
    let mut responses = Vec::with_capacity(n);
    for (i, x) in test_x.iter().enumerate() {
        session.submit(InferenceRequest::new(i as u64, x.clone()));
        responses.extend(session.poll(false)?);
    }
    responses.extend(session.poll(true)?);
    let wall = t0.elapsed().as_secs_f64();

    responses.sort_by_key(|r| r.id);
    let classes: Vec<Option<usize>> = responses.iter().map(|r| r.class).collect();
    Ok((classes, wall, session.metrics().energy_per_dec()))
}

fn main() -> anyhow::Result<()> {
    let has_artifacts = std::path::Path::new("artifacts/manifest.json").exists();
    println!("== DT2CAM end-to-end serving (covid @ S=128, batch 32) ==");
    let model = Dt2Cam::dataset("covid")?;
    let program = model.compile();
    let mapped = program.map(128, &DeviceParams::default());
    let n = model.test_x.len();
    println!(
        "workload: {} train / {} serve requests, LUT {}x{}",
        model.split.train.len(),
        n,
        program.lut().n_rows(),
        program.lut().width()
    );

    let acc = |cls: &[Option<usize>]| {
        cls.iter()
            .zip(&model.test_y[..n])
            .filter(|(c, y)| **c == Some(**y))
            .count() as f64
            / n as f64
    };

    // Native backend first (always available), then threaded-native as a
    // same-numerics, different-threading oracle.
    let (native, wall_native, e_native) =
        serve(EngineKind::Native, &mapped, &model.test_x, 32)?;
    println!(
        "native          : {n} decisions in {wall_native:.3}s -> {:.0} dec/s wall, accuracy {:.4}, modeled {}",
        n as f64 / wall_native,
        acc(&native),
        eng(e_native, "J/dec"),
    );

    let (threaded, wall_threaded, _) =
        serve(EngineKind::ThreadedNative, &mapped, &model.test_x, 32)?;
    println!(
        "threaded-native : {n} decisions in {wall_threaded:.3}s -> {:.0} dec/s wall, accuracy {:.4}",
        n as f64 / wall_threaded,
        acc(&threaded),
    );
    assert_eq!(native, threaded, "backends must agree on every classification");

    if has_artifacts {
        let (pjrt, wall_pjrt, e_pjrt) =
            serve(EngineKind::Pjrt, &mapped, &model.test_x, 32)?;
        println!(
            "pjrt            : {n} decisions in {wall_pjrt:.3}s -> {:.0} dec/s wall, accuracy {:.4}, modeled {}",
            n as f64 / wall_pjrt,
            acc(&pjrt),
            eng(e_pjrt, "J/dec"),
        );
        assert_eq!(native, pjrt, "engines must agree on every classification");
        println!("ok: PJRT artifacts and native simulator agree on all {n} decisions");
    } else {
        println!("(PJRT pass skipped: run `make artifacts` first)");
    }

    // Golden check: ideal hardware == software tree.
    let golden_agree = native
        .iter()
        .zip(&model.golden[..n])
        .filter(|(c, g)| **c == Some(**g))
        .count();
    assert_eq!(golden_agree, n, "ideal hardware must match golden predictions");
    println!(
        "golden agreement {}/{} | golden accuracy {:.4}",
        golden_agree,
        n,
        model.golden_accuracy()
    );
    Ok(())
}
