//! Regenerate every paper table (II, IV, V, VI) plus the Fig 9 scatter in
//! one run. Use `--full` to include the Credit dataset in Table V
//! (~4 s extra CART training).
//!
//! ```sh
//! cargo run --release --example paper_tables [-- --full]
//! ```

use dt2cam::report::figures::{fig9, render_fig9};
use dt2cam::report::tables::{
    render_table2, render_table4, render_table5, render_table6, table2, table4, table5,
    table6,
};
use dt2cam::report::workload::Workload;
use dt2cam::tcam::params::DeviceParams;

fn main() -> anyhow::Result<()> {
    let full = std::env::args().any(|a| a == "--full");
    let p = DeviceParams::default();

    print!("{}", render_table2(&table2()?));
    println!();
    print!("{}", render_table4(&table4(&p)));
    println!("  [paper: 154/128, 86/64, 53/32, 33/32, 21/16]\n");

    let mut names = vec![
        "iris", "diabetes", "haberman", "car", "cancer", "titanic", "covid",
    ];
    if full {
        names.push("credit");
    }
    let mut workloads = Vec::new();
    for n in &names {
        eprintln!("preparing {n}...");
        workloads.push(Workload::prepare(n)?);
    }
    let wrefs: Vec<&Workload> = workloads.iter().collect();
    print!("{}", render_table5(&table5(&wrefs)));
    println!("  [paper: iris 9x12 | diabetes 120x123 | haberman 93x71 | car 76x20");
    println!("          cancer 23x52 | credit 8475x3580 | titanic 191x150 | covid 441x146]\n");

    print!("{}", render_table6(&table6(&p)));
    println!("  [paper DT2CAM_128: 58.8e6 dec/s, 0.098 nJ/dec, 0.07 mm2, FOM 1.22e-19]\n");

    print!("{}", render_fig9(&fig9(&p)));
    Ok(())
}
