//! Wire-level serving demo: the socket server, client, and both load
//! generators, end to end on a loopback port.
//!
//! Spawns `net::Server` in-process over a 3-bank bagged forest
//! (haberman @S=16), sanity-checks a blocking client against the
//! in-process session, drives closed- and open-loop load, scrapes the
//! metrics frame, and shuts down gracefully. The same server is
//! reachable from a second process — see `dt2cam serve --listen` /
//! `dt2cam loadgen --connect` for the two-terminal flow.
//!
//! ```sh
//! cargo run --release --example net_serve
//! ```

use dt2cam::api::Dt2Cam;
use dt2cam::cart::ForestParams;
use dt2cam::config::EngineKind;
use dt2cam::net::{self, Client, Server, ServerConfig};
use dt2cam::tcam::params::DeviceParams;

fn main() -> anyhow::Result<()> {
    println!("== DT2CAM wire-level serving (3-bank forest, haberman @ S=16) ==");
    let fp = ForestParams {
        n_trees: 3,
        sample_fraction: 0.8,
        max_features: 2,
        ..Default::default()
    };
    let model = Dt2Cam::forest("haberman", &fp)?;
    let mapped = model.compile().map(16, &DeviceParams::default());
    let inputs = model.test_x.clone();

    // In-process oracle for the same program.
    let expected = mapped
        .session(EngineKind::Native, 8)?
        .classify_all(&inputs)?;

    // The server builds its coordinator on its own scheduler thread.
    let server = Server::spawn("127.0.0.1:0", ServerConfig::default(), move || {
        Ok(mapped
            .session(EngineKind::Native, 8)?
            .into_coordinator())
    })?;
    let addr = server.local_addr().to_string();
    println!("server listening on {addr}");

    // Blocking client: answers must match the in-process session.
    let mut client = Client::connect(&addr)?;
    for (i, x) in inputs.iter().enumerate().take(5) {
        let got = client.classify(x)?;
        assert_eq!(got, expected[i], "wire answer diverged on input {i}");
        println!("  request {i}: class {got:?} (matches in-process)");
    }

    // Closed-loop load: 4 clients, each waiting for its answer.
    let report = net::closed_loop(&addr, &inputs, 4, 200)?;
    println!("closed-loop : {}", report.summary_line());

    // Open-loop load: 2 connections pacing 1000 req/s aggregate.
    let report = net::open_loop(&addr, &inputs, 2, 1000.0, 300)?;
    println!("open-loop   : {}", report.summary_line());

    // Scrape the server-side roll-ups over the wire.
    let snap = client.metrics()?;
    println!("metrics     : {}", snap.summary_line());
    assert!(
        snap.decisions + snap.shed >= 505,
        "5 + 200 + 300 requests must be accounted for (answered or shed)"
    );

    // Graceful shutdown over the wire; join returns the final report.
    Client::connect(&addr)?.shutdown()?;
    let report = server.join()?;
    println!(
        "server stopped: conns={} shed={} | {}",
        report.connections,
        report.shed,
        report.metrics.summary_line()
    );
    println!("ok: wire serving matches the in-process coordinator");
    Ok(())
}
