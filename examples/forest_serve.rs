//! Forest serving quickstart: a bagged CART ensemble as a multi-bank
//! CAM program through the typed pipeline facade.
//!
//! `Dt2Cam::forest` trains N trees (bootstrap samples, optional feature
//! subsets); each tree compiles to its own LUT/tile **bank**; banks are
//! independent CAM arrays, so a `Send + Sync` backend searches them in
//! parallel and the session combines surviving classes with the
//! deterministic majority vote (ties → lowest class id). Hardware cost
//! follows `cart::forest`: energy sums over banks, modeled latency is
//! the slowest bank plus the vote stage.
//!
//! The same artifact flow as single trees applies — the mapped program
//! saves as a schema-v2 JSON artifact and serves from a separate
//! process (`dt2cam compile --dataset titanic --forest 9 --save f.json`
//! then `dt2cam serve --program f.json`).
//!
//! ```sh
//! cargo run --release --example forest_serve
//! ```

use dt2cam::api::{Dt2Cam, MappedProgram};
use dt2cam::cart::ForestParams;
use dt2cam::config::EngineKind;
use dt2cam::tcam::params::DeviceParams;
use dt2cam::util::stats::eng;

fn main() -> anyhow::Result<()> {
    println!("== DT2CAM forest serving (titanic, 9 banks @ S=16) ==");

    // 1. Train the ensemble (and the single tree it competes against).
    let single = Dt2Cam::dataset("titanic")?;
    let fp = ForestParams {
        n_trees: 9,
        sample_fraction: 0.8,
        max_features: 0, // all features per tree (bagging only)
        ..Default::default()
    };
    let model = Dt2Cam::forest("titanic", &fp)?;
    println!(
        "forest: {} banks, {} total leaves | golden accuracy {:.4} (single tree {:.4})",
        model.n_banks(),
        model.forest.total_leaves(),
        model.golden_accuracy(),
        single.golden_accuracy(),
    );

    // 2-3. Compile + map: one LUT and one tile grid per bank.
    let program = model.compile();
    let mapped = program.map(16, &DeviceParams::default());
    for (bi, (cb, mb)) in program.banks.iter().zip(&mapped.banks).enumerate() {
        println!(
            "  bank {bi}: LUT {:>3} x {:>2}, {} tiles, map_seed {:#x}",
            cb.lut.n_rows(),
            cb.lut.width(),
            mb.mapped.n_tiles(),
            mb.map_seed
        );
    }

    // Artifact round-trip: the v2 schema carries every bank.
    let path = std::env::temp_dir().join(format!("dt2cam_forest_{}.json", std::process::id()));
    mapped.save(&path)?;
    let mapped = MappedProgram::load(&path)?;
    std::fs::remove_file(&path).ok();
    assert_eq!(mapped.n_banks(), 9, "artifact must preserve all banks");

    // 4. Serve the test split: native and threaded-native both dispatch
    //    banks in parallel and must agree vote-for-vote.
    let mut native = mapped.session(EngineKind::Native, 32)?;
    println!(
        "session: engine={} banks={} bank-parallel={}",
        native.backend_name(),
        native.n_banks(),
        native.bank_parallel()
    );
    let t0 = std::time::Instant::now();
    let classes = native.classify_all(&model.test_x)?;
    let wall = t0.elapsed().as_secs_f64();

    let mut threaded = mapped.session(EngineKind::ThreadedNative, 32)?;
    let classes_t = threaded.classify_all(&model.test_x)?;
    assert_eq!(classes, classes_t, "backends must agree on every vote");

    // Ideal hardware: every bank matches its tree, so the combined vote
    // equals the software forest on every input.
    let golden_agree = classes
        .iter()
        .zip(&model.golden)
        .filter(|(c, g)| **c == Some(**g))
        .count();
    assert_eq!(golden_agree, classes.len(), "ideal hardware must match golden");

    let n = model.test_y.len();
    let acc = classes
        .iter()
        .zip(&model.test_y)
        .filter(|(c, y)| **c == Some(**y))
        .count() as f64
        / n as f64;
    println!(
        "served {n} requests in {wall:.3}s ({:.0} dec/s wall) | accuracy {acc:.4}",
        n as f64 / wall
    );
    println!(
        "modeled: energy/dec {} (sum over banks) | latency {} (slowest bank + vote)",
        eng(native.metrics().energy_per_dec(), "J"),
        eng(native.modeled_latency(), "s"),
    );
    let breakdown: Vec<String> = native
        .metrics()
        .bank_energy
        .iter()
        .map(|e| format!("{:.2}", e * 1e9 / native.metrics().decisions as f64))
        .collect();
    println!("per-bank nJ/dec: [{}]", breakdown.join(", "));
    println!("ok: 9-bank forest serves end-to-end with bit-identical votes across backends");
    Ok(())
}
